//! Subcommand implementations for the `repro` binary.

use std::path::{Path, PathBuf};

use crate::errors::{anyhow, Result};

use crate::cluster::Cluster;
use crate::config::types::load_run_config;
use crate::coordinator::builder::{build_tracker_streaming, RunConfig};
use crate::report::experiments::{self, ExpOpts};
use crate::report::table::{fnum, Table};
use crate::workload::generator::{stream, Mix, WorkloadConfig};
use crate::workload::trace::{self, TraceFormat, TraceReader, TraceStats, TraceWriter};
use crate::yarn::{yarn_policy_by_name, ResourceManager, YarnConfig};

use super::args::Args;

pub const USAGE: &str = "\
repro — Naive-Bayes Hadoop job scheduling (CS.DC 2015 reproduction)

USAGE:
  repro run        [--config cfg.toml] [--scheduler S] [--nodes N] [--racks R]
                   [--jobs J] [--rate R] [--seed S] [--mix M] [--csv DIR]
                   [--mtbf SECS] [--mttr SECS] [--timeline FILE.csv]
                   [--save-model FILE.json] [--load-model FILE.json]
                   [--record-events FILE.jsonl] [--explain] [obs flags]
  repro compare    [--jobs J] [--nodes N] [--seeds K] [--quick]
  repro experiment <e1..e14|all> [--quick] [--out DIR] [obs flags]
  repro yarn       [--policy P] [--jobs J] [--nodes N] [--seed S] [--explain]
                   [--mtbf SECS] [--mttr SECS] [--trace FILE] [obs flags]
  repro trace-gen  --out FILE [--jobs J] [--seed S] [--rate R] [--mix M]
                   [--format array|jsonl]
  repro trace-run  --trace FILE [--scheduler S] [--nodes N] [--seed S]
                   [obs flags]
  repro trace convert <in> <out> [--format array|jsonl]
  repro trace stats   <file>
  repro trace head    <file> [--n N]
  repro obs diff   <a.prom|a.jsonl> <b.prom|b.jsonl> [--match PREFIX]
                   [--fail-on PCT]
  repro obs check  --slo slo.json <dump.prom|dump.jsonl>
  repro lint       [--root DIR] [--trace FILE.jsonl] [--skip-churn]
  repro info

Schedulers: fifo fair capacity bayes bayes-blind bayes-xla random
            threshold-fifo
Policies:   any scheduler name (unified trait), plus the yarn-fifo,
            yarn-fair, yarn-capacity, yarn-bayes aliases
Mixes:      balanced | cpu_heavy|io_heavy|mem_heavy|net_heavy|small | cpu:<f>
Obs flags:  --obs-dump FILE.prom (Prometheus text snapshot)
            --obs-trace FILE.json (chrome://tracing spans)
            --obs-jsonl FILE.jsonl (metrics + spans + windows, JSONL v2)
            --obs-window SECS (close a metric-delta window every SECS
                               sim seconds; exported to JSONL/CSV)
            --obs-csv FILE.csv (long-format time-series of the windows)
            --obs-sample N (keep every Nth duration span, default 1)
            --verbose (enable warn/info driver logs, off by default)

`repro obs diff a b` compares two dumps (Prometheus or JSONL): scalar
deltas plus p50/p95/p99 shifts per histogram; `--match PREFIX` restricts
to matching metric names, `--fail-on PCT` exits 1 when any matched
change exceeds PCT percent. `repro obs check` evaluates a declarative
SLO spec (see OBSERVABILITY.md) against a dump and exits 1 on violation.

Traces stream end to end (TRACES.md): `trace-gen` writes specs as they
are generated, `trace-run` replays them through the tracker one spec
ahead of the virtual clock, and `repro trace convert/stats/head` are
one-pass — none of them ever hold the whole trace in memory. Both the
JSON-array and JSONL layouts are read transparently (sniffed from the
first byte); `--format` picks the output layout.
";

/// Dispatch a full command line (without argv[0]). Returns process exit code.
pub fn dispatch<I: IntoIterator<Item = String>>(raw: I) -> Result<i32> {
    let args = Args::parse(raw, &["quick", "verbose", "explain", "skip-churn"])?;
    if args.flag("verbose") {
        crate::obs::log::set_level(crate::obs::log::INFO);
    }
    let Some(cmd) = args.positionals.first().map(String::as_str) else {
        println!("{USAGE}");
        return Ok(2);
    };
    match cmd {
        "run" => cmd_run(&args),
        "compare" => cmd_compare(&args),
        "experiment" | "exp" => cmd_experiment(&args),
        "yarn" => cmd_yarn(&args),
        "trace-gen" => cmd_trace_gen(&args),
        "trace-run" => cmd_trace_run(&args),
        "trace" => cmd_trace(&args),
        "obs" => cmd_obs(&args),
        "lint" => cmd_lint(&args),
        "info" => cmd_info(),
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(0)
        }
        other => Err(anyhow!("unknown command '{other}'\n\n{USAGE}")),
    }
}

fn parse_mix(s: &str) -> Result<Mix> {
    if s == "balanced" {
        return Ok(Mix::balanced());
    }
    if let Some(f) = s.strip_prefix("cpu:") {
        return Ok(Mix::cpu_fraction(f.parse()?));
    }
    crate::job::profile::JobClass::from_name(s)
        .map(Mix::only)
        .ok_or_else(|| anyhow!("unknown mix '{s}'"))
}

/// Assemble a RunConfig from an optional TOML file + CLI overrides.
fn config_from_args(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => load_run_config(Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(s) = args.opt("scheduler") {
        cfg.scheduler = s.to_string();
    }
    cfg.n_nodes = args.opt_u64("nodes", cfg.n_nodes as u64)? as u32;
    cfg.n_racks = args.opt_u64("racks", cfg.n_racks as u64)? as u32;
    cfg.workload.n_jobs = args.opt_u64("jobs", cfg.workload.n_jobs as u64)? as usize;
    cfg.workload.arrival_rate = args.opt_f64("rate", cfg.workload.arrival_rate)?;
    cfg.workload.seed = args.opt_u64("seed", cfg.workload.seed)?;
    if let Some(m) = args.opt("mix") {
        cfg.workload.mix = parse_mix(m)?;
    }
    let mtbf = args.opt_f64("mtbf", 0.0)?;
    if mtbf > 0.0 {
        cfg.tracker.failures.mtbf = Some(mtbf);
    }
    cfg.tracker.failures.mttr = args.opt_f64("mttr", cfg.tracker.failures.mttr)?;
    if args.opt("timeline").is_some() {
        cfg.tracker.timeline_interval =
            args.opt_f64("timeline-interval", 15.0)?;
    }
    if let Some(p) = args.opt("load-model") {
        cfg.model_path = Some(PathBuf::from(p));
    }
    cfg.obs = obs_from_args(args)?;
    Ok(cfg)
}

/// Parse the shared `--obs-*` observability flags.
fn obs_from_args(args: &Args) -> Result<crate::obs::ObsOptions> {
    let window = args.opt_f64("obs-window", 0.0)?;
    Ok(crate::obs::ObsOptions {
        dump: args.opt("obs-dump").map(PathBuf::from),
        trace: args.opt("obs-trace").map(PathBuf::from),
        jsonl: args.opt("obs-jsonl").map(PathBuf::from),
        window: (window > 0.0).then_some(window),
        csv: args.opt("obs-csv").map(PathBuf::from),
        sample: args.opt_u64("obs-sample", 1)?.max(1),
        verbose: args.flag("verbose"),
    })
}

fn summary_table(rows: &[crate::report::experiments::common::RunSummary]) -> Table {
    let mut t = Table::new(
        "run summary",
        &[
            "scheduler",
            "seed",
            "makespan_s",
            "throughput",
            "mean_latency_s",
            "p95_latency_s",
            "overload_rate",
            "oom",
            "node_local",
            "decision_us",
        ],
    );
    for r in rows {
        t.row(vec![
            r.scheduler.clone(),
            format!("{}", r.seed),
            fnum(r.makespan),
            fnum(r.throughput),
            fnum(r.mean_latency),
            fnum(r.p95_latency),
            fnum(r.overload_rate),
            format!("{}", r.oom_kills),
            fnum(r.locality_node),
            fnum(r.mean_decision_us),
        ]);
    }
    t
}

fn cmd_run(args: &Args) -> Result<i32> {
    let cfg = config_from_args(args)?;
    let cluster = Cluster::homogeneous(cfg.n_nodes, cfg.n_racks);
    println!(
        "running {} jobs on {} nodes ({} racks) with scheduler '{}'",
        cfg.workload.n_jobs,
        cfg.n_nodes,
        cfg.n_racks,
        cfg.scheduler
    );
    // specs stream into existence one arrival ahead of the clock — a
    // large --jobs run never materializes its workload
    let specs: Box<dyn Iterator<Item = crate::job::job::JobSpec>> =
        Box::new(stream(&cfg.workload));
    let mut jt = build_tracker_streaming(&cfg, cluster, specs)?;
    jt.metrics.explain = args.flag("explain");
    if args.opt("record-events").is_some() {
        jt.set_audit(crate::analysis::protocol::AuditSink::recording());
    }
    if cfg.obs.any_output() {
        jt.enable_obs(&cfg.obs);
    }
    let t0 = crate::obs::Stopwatch::start();
    jt.run();
    let wall = t0.elapsed_secs();
    jt.finish_obs(&cfg.obs)?;
    for (p, what) in [
        (&cfg.obs.dump, "prometheus snapshot"),
        (&cfg.obs.trace, "chrome trace"),
        (&cfg.obs.jsonl, "obs jsonl"),
        (&cfg.obs.csv, "time-series csv"),
    ] {
        if let Some(p) = p {
            println!("wrote {what} to {}", p.display());
        }
    }
    if let Some(path) = args.opt("record-events") {
        let events = jt.audit.take_recording();
        std::fs::write(path, crate::analysis::trace::to_jsonl(&events))?;
        println!("recorded {} audit events to {path}", events.len());
    }
    let summary = crate::report::experiments::common::summarize(&jt, &cfg);
    let table = summary_table(std::slice::from_ref(&summary));
    println!("{}", table.render());
    println!(
        "virtual makespan {:.1}s simulated in {:.2}s wall ({} events, {} heartbeats)",
        jt.metrics.makespan,
        wall,
        jt.engine.processed(),
        jt.metrics.heartbeats
    );
    if let Some(dir) = args.opt("csv") {
        table.save_csv(Path::new(dir), "run")?;
        println!("wrote {dir}/run.csv");
    }
    if let Some(path) = args.opt("timeline") {
        std::fs::write(path, jt.metrics.timeline.to_csv())?;
        println!("wrote {} timeline samples to {path}", jt.metrics.timeline.len());
    }
    if let Some(path) = args.opt("save-model") {
        match jt.scheduler.export_model() {
            Some(model) => {
                std::fs::write(path, model.to_string_pretty())?;
                println!("saved model to {path}");
            }
            None => println!("scheduler '{}' has no model to save", cfg.scheduler),
        }
    }
    if jt.metrics.node_failures > 0 || jt.metrics.task_failures > 0 {
        println!(
            "failures: {} node, {} task attempts (jobs killed: {})",
            jt.metrics.node_failures,
            jt.metrics.task_failures,
            jt.metrics.failed_jobs
        );
    }
    if jt.metrics.speculative_launches > 0 {
        println!(
            "speculation: {} backup copies launched, {} won their race",
            jt.metrics.speculative_launches, jt.metrics.speculative_wins
        );
    }
    if jt.engine.clamped_events() > 0 {
        println!(
            "warning: {} past-time events clamped to now",
            jt.engine.clamped_events()
        );
    }
    print_explain(&jt.metrics, args);
    Ok(0)
}

/// `--explain`: dump the per-assignment decision trace.
fn print_explain(m: &crate::metrics::Metrics, args: &Args) {
    if !args.flag("explain") {
        return;
    }
    println!(
        "decision trace: {} assignments over {} heartbeat batches",
        m.decision_log.len(),
        m.assign_calls()
    );
    for rec in &m.decision_log {
        println!("  {rec}");
    }
}

fn cmd_compare(args: &Args) -> Result<i32> {
    let seeds = args.opt_u64("seeds", 3)?;
    let mut rows = Vec::new();
    for sched in ["fifo", "fair", "capacity", "bayes"] {
        for seed in 1..=seeds {
            let mut cfg = config_from_args(args)?;
            cfg.scheduler = sched.to_string();
            cfg.workload.seed = seed;
            rows.push(crate::report::experiments::common::run_once(&cfg));
        }
    }
    println!("{}", summary_table(&rows).render());
    Ok(0)
}

fn cmd_experiment(args: &Args) -> Result<i32> {
    let id = args
        .positionals
        .get(1)
        .ok_or_else(|| anyhow!("experiment id required (e1..e14 or all)"))?;
    let opts = ExpOpts {
        quick: args.flag("quick"),
        out_dir: args.opt("out").map(PathBuf::from),
        obs: obs_from_args(args)?,
    };
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        let t0 = crate::obs::Stopwatch::start();
        let tables = experiments::run(id, &opts)
            .ok_or_else(|| anyhow!("unknown experiment '{id}'"))?;
        for t in &tables {
            println!("{}", t.render());
        }
        println!("[{id} took {:.1}s]\n", t0.elapsed_secs());
    }
    Ok(0)
}

fn cmd_yarn(args: &Args) -> Result<i32> {
    let policy = args.opt_or("policy", "yarn-bayes");
    let nodes = args.opt_u64("nodes", 40)? as u32;
    let seed = args.opt_u64("seed", 1)?;
    // --trace replays a saved trace; otherwise specs stream from the
    // generator. Either way the workload is never materialized.
    let mut trace_tap = None;
    let specs: Box<dyn Iterator<Item = crate::job::job::JobSpec>> =
        match args.opt("trace") {
            Some(path) => {
                let mut reader = TraceReader::open(Path::new(path))?;
                let stats = TraceStats::default();
                reader.install_stats(stats.clone());
                let (specs, errs) = reader.into_stream();
                trace_tap = Some((stats, errs, path.to_string()));
                specs
            }
            None => Box::new(stream(&WorkloadConfig {
                n_jobs: args.opt_u64("jobs", 100)? as usize,
                arrival_rate: args.opt_f64("rate", 0.5)?,
                seed,
                ..Default::default()
            })),
        };
    let cluster = Cluster::homogeneous(nodes, (nodes / 10).max(1));
    let mut ycfg = YarnConfig::default();
    let mtbf = args.opt_f64("mtbf", 0.0)?;
    if mtbf > 0.0 {
        ycfg.failures.mtbf = Some(mtbf);
    }
    ycfg.failures.mttr = args.opt_f64("mttr", ycfg.failures.mttr)?;
    let mut rm = ResourceManager::new_streaming(
        cluster,
        yarn_policy_by_name(policy, 1.0)?,
        specs,
        seed,
        ycfg,
    );
    rm.metrics.explain = args.flag("explain");
    let obs = obs_from_args(args)?;
    if obs.any_output() {
        rm.enable_obs(&obs);
    }
    rm.run();
    if let Some((stats, errs, path)) = &trace_tap {
        if let Some(e) = errs.take() {
            return Err(e.wrap(format!("replaying trace {path}")));
        }
        println!(
            "replayed {} specs ({} bytes) from {path}",
            stats.specs_read(),
            stats.bytes_read()
        );
        if let Some(r) = rm.obs.registry() {
            install_trace_stats(&r, stats);
        }
    }
    rm.finish_obs(&obs)?;
    let m = &rm.metrics;
    let mut t = Table::new(
        "yarn run",
        &["policy", "makespan_s", "mean_latency_s", "overload_rate", "oom"],
    );
    t.row(vec![
        policy.into(),
        fnum(m.makespan),
        fnum(m.mean_latency()),
        fnum(m.overload_rate()),
        format!("{}", m.oom_kills),
    ]);
    println!("{}", t.render());
    print_explain(&rm.metrics, args);
    Ok(0)
}

/// Parse `--format array|jsonl` (with `default` when absent).
fn format_arg(args: &Args, default: TraceFormat) -> Result<TraceFormat> {
    match args.opt("format") {
        None => Ok(default),
        Some(s) => TraceFormat::from_name(s)
            .ok_or_else(|| anyhow!("unknown trace format '{s}' (array|jsonl)")),
    }
}

/// Mirror finished ingest stats into a driver's live registry so the
/// `trace_*` metrics ride the normal obs exporters.
fn install_trace_stats(r: &crate::obs::Registry, stats: &TraceStats) {
    r.counter("trace_specs_read").add(stats.specs_read());
    r.counter("trace_bytes_read").add(stats.bytes_read());
    r.counter("trace_ingest_nanos").add(stats.ingest_nanos());
    r.gauge("trace_ingest_resident").set(stats.resident_peak());
}

fn cmd_trace_gen(args: &Args) -> Result<i32> {
    let out = args.opt("out").ok_or_else(|| anyhow!("--out FILE required"))?;
    let cfg = WorkloadConfig {
        n_jobs: args.opt_u64("jobs", 200)? as usize,
        arrival_rate: args.opt_f64("rate", 0.5)?,
        mix: parse_mix(args.opt_or("mix", "balanced"))?,
        n_users: args.opt_u64("users", 8)? as usize,
        seed: args.opt_u64("seed", 1)?,
    };
    let format = format_arg(args, TraceFormat::Array)?;
    // specs flow generator -> writer one at a time
    let n = trace::save_stream(stream(&cfg), Path::new(out), format)?;
    println!("wrote {n} jobs to {out} ({})", format.name());
    Ok(0)
}

fn cmd_trace_run(args: &Args) -> Result<i32> {
    let path = args.opt("trace").ok_or_else(|| anyhow!("--trace FILE required"))?;
    let cfg = config_from_args(args)?;
    let cluster = Cluster::homogeneous(cfg.n_nodes, cfg.n_racks);
    let mut reader = TraceReader::open(Path::new(path))?;
    let stats = TraceStats::default();
    reader.install_stats(stats.clone());
    let (specs, errs) = reader.into_stream();
    let mut jt = build_tracker_streaming(&cfg, cluster, specs)?;
    if cfg.obs.any_output() {
        jt.enable_obs(&cfg.obs);
    }
    jt.run();
    if let Some(e) = errs.take() {
        return Err(e.wrap(format!("replaying trace {path}")));
    }
    println!(
        "replayed {} specs ({} bytes, peak ingest resident {} bytes)",
        stats.specs_read(),
        stats.bytes_read(),
        stats.resident_peak()
    );
    if let Some(r) = jt.obs.registry() {
        install_trace_stats(&r, &stats);
    }
    jt.finish_obs(&cfg.obs)?;
    let summary = crate::report::experiments::common::summarize(&jt, &cfg);
    println!("{}", summary_table(&[summary]).render());
    Ok(0)
}

/// `repro trace <convert|stats|head>`: one-pass streaming trace tools —
/// none of them ever hold more than one spec in memory.
fn cmd_trace(args: &Args) -> Result<i32> {
    match args.positionals.get(1).map(String::as_str) {
        Some("convert") => cmd_trace_convert(args),
        Some("stats") => cmd_trace_stats(args),
        Some("head") => cmd_trace_head(args),
        _ => Err(anyhow!(
            "usage: repro trace convert <in> <out> [--format array|jsonl]\n\
             \x20      repro trace stats <file>\n\
             \x20      repro trace head <file> [--n N]"
        )),
    }
}

fn cmd_trace_convert(args: &Args) -> Result<i32> {
    let (Some(src), Some(dst)) = (args.positionals.get(2), args.positionals.get(3))
    else {
        return Err(anyhow!(
            "usage: repro trace convert <in> <out> [--format array|jsonl]"
        ));
    };
    let reader = TraceReader::open(Path::new(src))?;
    // default: translate to the other layout
    let default = match reader.format() {
        TraceFormat::Array => TraceFormat::Jsonl,
        TraceFormat::Jsonl => TraceFormat::Array,
    };
    let format = format_arg(args, default)?;
    let file = std::fs::File::create(Path::new(dst))?;
    let mut w = TraceWriter::new(std::io::BufWriter::new(file), format);
    let mut n = 0u64;
    for spec in reader {
        w.write_spec(&spec.map_err(|e| e.wrap(format!("reading {src}")))?)?;
        n += 1;
    }
    let written = w.finish()?;
    debug_assert_eq!(written, n);
    println!("converted {n} specs: {src} -> {dst} ({})", format.name());
    Ok(0)
}

fn cmd_trace_stats(args: &Args) -> Result<i32> {
    let Some(path) = args.positionals.get(2) else {
        return Err(anyhow!("usage: repro trace stats <file>"));
    };
    let mut reader = TraceReader::open(Path::new(path))?;
    let format = reader.format();
    let mut n = 0u64;
    let mut maps = 0u64;
    let mut reduces = 0u64;
    let mut first_submit = f64::INFINITY;
    let mut last_submit = f64::NEG_INFINITY;
    let mut peak_resident = 0usize;
    while let Some(item) = reader.next() {
        let spec = item.map_err(|e| e.wrap(format!("reading {path}")))?;
        n += 1;
        maps += spec.map_works.len() as u64;
        reduces += spec.reduce_works.len() as u64;
        first_submit = first_submit.min(spec.submit_time);
        last_submit = last_submit.max(spec.submit_time);
        peak_resident = peak_resident.max(reader.resident_bytes());
    }
    let mut t = Table::new(
        &format!("trace stats: {path}"),
        &["format", "specs", "bytes", "maps", "reduces", "first_submit", "last_submit", "peak_resident"],
    );
    t.row(vec![
        format.name().into(),
        format!("{n}"),
        format!("{}", reader.bytes_read()),
        format!("{maps}"),
        format!("{reduces}"),
        if n == 0 { "-".into() } else { fnum(first_submit) },
        if n == 0 { "-".into() } else { fnum(last_submit) },
        format!("{peak_resident}"),
    ]);
    println!("{}", t.render());
    Ok(0)
}

fn cmd_trace_head(args: &Args) -> Result<i32> {
    let Some(path) = args.positionals.get(2) else {
        return Err(anyhow!("usage: repro trace head <file> [--n N]"));
    };
    let n = args.opt_u64("n", 10)?;
    let reader = TraceReader::open(Path::new(path))?;
    let mut w = TraceWriter::new(std::io::stdout(), TraceFormat::Jsonl);
    for item in reader.take(n as usize) {
        w.write_spec(&item.map_err(|e| e.wrap(format!("reading {path}")))?)?;
    }
    w.finish()?;
    Ok(0)
}

/// `repro obs <diff|check>`: the offline half of the observatory —
/// regression diffs between two metric dumps and declarative SLO gates
/// over one.
fn cmd_obs(args: &Args) -> Result<i32> {
    match args.positionals.get(1).map(String::as_str) {
        Some("diff") => cmd_obs_diff(args),
        Some("check") => cmd_obs_check(args),
        _ => Err(anyhow!(
            "usage: repro obs diff <a> <b> [--match PREFIX] [--fail-on PCT]\n\
             \x20      repro obs check --slo slo.json <dump>"
        )),
    }
}

/// Percent change from `old` to `new`; a metric appearing or vanishing
/// counts as a 100% change so `--fail-on` still gates it.
fn pct_change(old: f64, new: f64) -> f64 {
    if old == new {
        // bit-identical fast path
        0.0
    // appeared from nothing: treat as a 100% shift -- lint: allow(float-eq)
    } else if old == 0.0 {
        100.0
    } else {
        (new - old) / old.abs() * 100.0
    }
}

fn cmd_obs_diff(args: &Args) -> Result<i32> {
    let (Some(a_path), Some(b_path)) = (args.positionals.get(2), args.positionals.get(3)) else {
        return Err(anyhow!("usage: repro obs diff <a> <b> [--match PREFIX] [--fail-on PCT]"));
    };
    let a = crate::obs::export::load_dump(Path::new(a_path))?;
    let b = crate::obs::export::load_dump(Path::new(b_path))?;
    let prefix = args.opt_or("match", "");
    let fail_on = match args.opt("fail-on") {
        Some(_) => Some(args.opt_f64("fail-on", 0.0)?),
        None => None,
    };

    let mut worst: f64 = 0.0;
    let mut unchanged = 0usize;
    let mut t = Table::new(
        &format!("obs diff: {a_path} -> {b_path}"),
        &["metric", "old", "new", "delta_pct"],
    );
    let names: std::collections::BTreeSet<&String> =
        a.scalars.keys().chain(b.scalars.keys()).collect();
    for name in names {
        if !name.starts_with(prefix) {
            continue;
        }
        let old = a.value(name).unwrap_or(0.0);
        let new = b.value(name).unwrap_or(0.0);
        let pct = pct_change(old, new);
        // only changed metrics earn a row -- lint: allow(float-eq)
        if pct == 0.0 {
            unchanged += 1;
            continue;
        }
        worst = worst.max(pct.abs());
        t.row(vec![
            name.clone(),
            fnum(old),
            fnum(new),
            format!("{pct:+.2}"),
        ]);
    }
    let hist_names: std::collections::BTreeSet<&String> =
        a.hists.keys().chain(b.hists.keys()).collect();
    for name in hist_names {
        if !name.starts_with(prefix) {
            continue;
        }
        let pa = a.hists.get(name).map(crate::obs::Percentiles::of).unwrap_or_default();
        let pb = b.hists.get(name).map(crate::obs::Percentiles::of).unwrap_or_default();
        for (tag, old, new) in [
            ("p50", pa.p50, pb.p50),
            ("p95", pa.p95, pb.p95),
            ("p99", pa.p99, pb.p99),
        ] {
            let pct = pct_change(old, new);
            // zero shift earns no row -- lint: allow(float-eq)
            if pct == 0.0 {
                unchanged += 1;
                continue;
            }
            worst = worst.max(pct.abs());
            t.row(vec![
                format!("{name}:{tag}"),
                fnum(old),
                fnum(new),
                format!("{pct:+.2}"),
            ]);
        }
    }
    println!("{}", t.render());
    println!(
        "{unchanged} matched sample(s) unchanged; worst shift {worst:.2}%{}",
        if prefix.is_empty() {
            String::new()
        } else {
            format!(" (filter: '{prefix}')")
        }
    );
    if let Some(limit) = fail_on {
        if worst > limit {
            println!("obs diff: FAIL (worst {worst:.2}% > --fail-on {limit}%)");
            return Ok(1);
        }
        println!("obs diff: within --fail-on {limit}%");
    }
    Ok(0)
}

fn cmd_obs_check(args: &Args) -> Result<i32> {
    let slo_path = args
        .opt("slo")
        .ok_or_else(|| anyhow!("--slo slo.json required"))?;
    let dump_path = args
        .positionals
        .get(2)
        .ok_or_else(|| anyhow!("usage: repro obs check --slo slo.json <dump>"))?;
    let spec = crate::obs::slo::SloSpec::load(Path::new(slo_path))?;
    let dump = crate::obs::export::load_dump(Path::new(dump_path))?;
    // bench rules resolve relative to the working directory (repo root
    // in CI), same as the spec author sees them
    let violations = spec.evaluate(&dump, Path::new("."));
    for v in &violations {
        println!("{dump_path}: {v}");
    }
    println!(
        "obs check: {} rule(s), {} violation(s)",
        spec.rules.len(),
        violations.len()
    );
    if violations.is_empty() {
        println!("obs check: PASS");
        Ok(0)
    } else {
        println!("obs check: FAIL");
        Ok(1)
    }
}

/// `repro lint`: the project's own static analysis (LINTS.md) plus the
/// SchedEvent protocol audit — offline over `--trace FILE` when given,
/// otherwise the built-in fail/recover churn sweep over every scheduler
/// under both drivers. Exit code 1 on any finding or violation (CI gate).
fn cmd_lint(args: &Args) -> Result<i32> {
    let root = PathBuf::from(args.opt_or("root", "."));
    if !root.join("rust/src").is_dir() {
        return Err(anyhow!(
            "{} does not look like the repo root (no rust/src); pass --root",
            root.display()
        ));
    }
    let mut bad = 0usize;

    let findings = crate::analysis::source::run_lints(&root)?;
    for f in &findings {
        println!("{f}");
    }
    println!(
        "source lints: {} finding(s) across {} lint(s)",
        findings.len(),
        crate::analysis::source::LINT_NAMES.len()
    );
    bad += findings.len();

    if let Some(path) = args.opt("trace") {
        let text = std::fs::read_to_string(path)?;
        let events = crate::analysis::trace::from_jsonl(&text)?;
        let violations = crate::analysis::protocol::audit_stream(&events);
        for v in &violations {
            println!("{path}: {v}");
        }
        println!(
            "protocol audit ({path}): {} event(s), {} violation(s)",
            events.len(),
            violations.len()
        );
        bad += violations.len();
    }

    if !args.flag("skip-churn") {
        for rep in crate::analysis::audit_all_schedulers(7)? {
            for v in &rep.violations {
                println!("churn {}/{}: {v}", rep.driver, rep.scheduler);
            }
            bad += rep.violations.len();
        }
        println!(
            "churn conformance: {} scheduler(s) x 2 drivers audited",
            crate::scheduler::ALL_NAMES.len()
        );
    }

    if bad > 0 {
        println!("repro lint: FAIL ({bad} problem(s))");
        Ok(1)
    } else {
        println!("repro lint: clean");
        Ok(0)
    }
}

fn cmd_info() -> Result<i32> {
    println!("bayes-sched {}", env!("CARGO_PKG_VERSION"));
    let dir = crate::runtime::artifacts::default_dir();
    match crate::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: OK at {dir:?}");
            println!("  classify: {:?} (sha256 {}…)", m.classify.path, &m.classify.sha256[..12]);
            println!("  update:   {:?} (sha256 {}…)", m.update.path, &m.update.sha256[..12]);
            match crate::runtime::Runtime::load(&dir) {
                Ok(rt) => println!("  PJRT platform: {}", rt.platform()),
                Err(e) => println!("  PJRT load FAILED: {e:#}"),
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}) — `make artifacts`"),
    }
    println!("schedulers: {}", crate::scheduler::ALL_NAMES.join(" "));
    println!("experiments: {}", crate::report::experiments::ALL.join(" "));
    Ok(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn usage_on_no_args() {
        assert_eq!(dispatch(Vec::<String>::new()).unwrap(), 2);
    }

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(vec!["frobnicate".to_string()]).is_err());
    }

    #[test]
    fn tiny_run_via_cli() {
        let code = dispatch(
            "run --scheduler fifo --nodes 4 --jobs 5 --seed 3"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn trace_roundtrip_via_cli() {
        let dir = std::env::temp_dir();
        let path = dir.join("bayes_sched_cli_trace.json");
        let gen_cmd = format!("trace-gen --out {} --jobs 5 --seed 2", path.display());
        assert_eq!(dispatch(gen_cmd.split_whitespace().map(String::from)).unwrap(), 0);
        let run_cmd = format!(
            "trace-run --trace {} --scheduler bayes --nodes 4",
            path.display()
        );
        assert_eq!(dispatch(run_cmd.split_whitespace().map(String::from)).unwrap(), 0);
    }

    #[test]
    fn explain_flag_produces_a_trace() {
        let code = dispatch(
            "run --scheduler bayes --nodes 3 --jobs 4 --seed 6 --explain"
                .split_whitespace()
                .map(String::from),
        )
        .unwrap();
        assert_eq!(code, 0);
    }

    #[test]
    fn record_events_then_lint_trace_via_cli() {
        let path = std::env::temp_dir().join("bayes_sched_cli_events.jsonl");
        let run_cmd = format!(
            "run --scheduler fifo --nodes 4 --jobs 5 --seed 3 --record-events {}",
            path.display()
        );
        assert_eq!(dispatch(run_cmd.split_whitespace().map(String::from)).unwrap(), 0);
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
        let lint_cmd = format!(
            "lint --root {} --trace {} --skip-churn",
            root.display(),
            path.display()
        );
        assert_eq!(
            dispatch(lint_cmd.split_whitespace().map(String::from)).unwrap(),
            0,
            "repro lint found problems in the repo or the recorded trace"
        );
    }

    fn scratch_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("repro_cli_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn write_prom(dir: &Path, file: &str, started: u64, failed: u64) -> PathBuf {
        let r = crate::obs::Registry::new();
        r.counter("sched_ev_task_started").add(started);
        r.counter("sched_ev_task_failed").add(failed);
        let h = r.histogram("driver_queue_depth");
        for v in 0..started {
            h.record(v);
        }
        let path = dir.join(file);
        std::fs::write(&path, crate::obs::export::to_prometheus(&r.snapshot())).unwrap();
        path
    }

    #[test]
    fn obs_diff_gates_on_fail_on() {
        let dir = scratch_dir("diff");
        let a = write_prom(&dir, "a.prom", 100, 2);
        let b = write_prom(&dir, "b.prom", 100, 3); // failed +50%
        let same = |x: &Path, y: &Path, extra: &str| {
            let cmd = format!("obs diff {} {} {extra}", x.display(), y.display());
            dispatch(cmd.split_whitespace().map(String::from)).unwrap()
        };
        assert_eq!(same(&a, &a, ""), 0, "self-diff is clean");
        assert_eq!(same(&a, &a, "--fail-on 0"), 0, "self-diff passes any gate");
        assert_eq!(same(&a, &b, ""), 0, "no gate, report only");
        assert_eq!(same(&a, &b, "--fail-on 10"), 1, "+50% breaches 10%");
        assert_eq!(same(&a, &b, "--fail-on 60"), 0, "+50% fits under 60%");
        assert_eq!(
            same(&a, &b, "--match sched_ev_task_started --fail-on 10"),
            0,
            "the changed metric is filtered out by --match"
        );
        assert!(dispatch(vec!["obs".into()]).is_err(), "missing subcommand");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn obs_check_evaluates_the_slo_spec() {
        let dir = scratch_dir("check");
        let dump = write_prom(&dir, "m.prom", 100, 2);
        let ok_spec = dir.join("ok.json");
        std::fs::write(
            &ok_spec,
            r#"{"slo":[
                {"kind":"value","metric":"obs_collisions","max":0},
                {"kind":"ratio","num":"sched_ev_task_failed","den":"sched_ev_task_started","max":0.05}
            ]}"#,
        )
        .unwrap();
        let bad_spec = dir.join("bad.json");
        std::fs::write(
            &bad_spec,
            r#"{"slo":[{"kind":"value","metric":"sched_ev_task_failed","max":1}]}"#,
        )
        .unwrap();
        let check = |spec: &Path| {
            let cmd = format!("obs check --slo {} {}", spec.display(), dump.display());
            dispatch(cmd.split_whitespace().map(String::from)).unwrap()
        };
        assert_eq!(check(&ok_spec), 0);
        assert_eq!(check(&bad_spec), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn windowed_run_via_cli_writes_the_csv() {
        let dir = scratch_dir("window");
        let csv = dir.join("ts.csv");
        let jsonl = dir.join("o.jsonl");
        let cmd = format!(
            "run --scheduler fifo --nodes 4 --jobs 8 --seed 3 --obs-window 60 \
             --obs-csv {} --obs-jsonl {}",
            csv.display(),
            jsonl.display()
        );
        assert_eq!(dispatch(cmd.split_whitespace().map(String::from)).unwrap(), 0);
        let text = std::fs::read_to_string(&csv).unwrap();
        assert!(text.starts_with("window,sim_start,sim_end,"));
        assert!(text.lines().count() > 1, "windowed run must emit rows");
        let doc = crate::obs::export::parse_jsonl(&std::fs::read_to_string(&jsonl).unwrap())
            .expect("jsonl parses");
        assert!(!doc.windows.is_empty(), "jsonl carries the window series");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn trace_convert_stats_head_via_cli() {
        let dir = scratch_dir("trace");
        let arr = dir.join("t.json");
        let jl = dir.join("t.jsonl");
        let gen_cmd = format!("trace-gen --out {} --jobs 6 --seed 5", arr.display());
        assert_eq!(dispatch(gen_cmd.split_whitespace().map(String::from)).unwrap(), 0);
        let conv = format!("trace convert {} {}", arr.display(), jl.display());
        assert_eq!(dispatch(conv.split_whitespace().map(String::from)).unwrap(), 0);
        // the converted JSONL replays through the streaming tracker path
        let run_cmd = format!(
            "trace-run --trace {} --scheduler fifo --nodes 4",
            jl.display()
        );
        assert_eq!(dispatch(run_cmd.split_whitespace().map(String::from)).unwrap(), 0);
        let stats = format!("trace stats {}", jl.display());
        assert_eq!(dispatch(stats.split_whitespace().map(String::from)).unwrap(), 0);
        let head = format!("trace head {} --n 2", jl.display());
        assert_eq!(dispatch(head.split_whitespace().map(String::from)).unwrap(), 0);
        assert!(dispatch(vec!["trace".to_string()]).is_err(), "missing subcommand");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jsonl_trace_replays_through_yarn_via_cli() {
        let dir = scratch_dir("ytrace");
        let jl = dir.join("y.jsonl");
        let gen_cmd = format!(
            "trace-gen --out {} --jobs 5 --seed 7 --format jsonl",
            jl.display()
        );
        assert_eq!(dispatch(gen_cmd.split_whitespace().map(String::from)).unwrap(), 0);
        let yarn_cmd = format!(
            "yarn --policy yarn-fifo --nodes 4 --trace {}",
            jl.display()
        );
        assert_eq!(dispatch(yarn_cmd.split_whitespace().map(String::from)).unwrap(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quick_experiment_via_cli() {
        let code = dispatch(
            "experiment e5 --quick".split_whitespace().map(String::from),
        )
        .unwrap();
        assert_eq!(code, 0);
    }
}
