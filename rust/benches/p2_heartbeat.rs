//! Bench p2_heartbeat: coordinator throughput — events and heartbeats
//! processed per second of wall time on a large cluster, per scheduler.
//! The L3 target (DESIGN.md §7): the scheduler must never be the
//! simulation bottleneck.
//!
//!     cargo bench --bench p2_heartbeat

use bayes_sched::cluster::Cluster;
use bayes_sched::coordinator::jobtracker::{JobTracker, TrackerConfig};
use bayes_sched::report::bench::{bench, fmt_ns};
use bayes_sched::scheduler;
use bayes_sched::workload::generator::{generate, WorkloadConfig};

fn main() {
    println!("== coordinator event-loop throughput (160 nodes, 400 jobs) ==");
    for sched in ["fifo", "bayes"] {
        let mut total_events = 0u64;
        let mut total_heartbeats = 0u64;
        let m = bench(&format!("coordinator/{sched}/160n_400j"), 0, 3, |i| {
            let cluster = Cluster::homogeneous(160, 8);
            let specs = generate(&WorkloadConfig {
                n_jobs: 400,
                arrival_rate: 2.0,
                seed: 1 + i as u64,
                ..Default::default()
            });
            let mut jt = JobTracker::new(
                cluster,
                scheduler::by_name(sched, 1).unwrap(),
                specs,
                1,
                TrackerConfig::default(),
            );
            jt.run();
            total_events += jt.engine.processed();
            total_heartbeats += jt.metrics.heartbeats;
        });
        let events_per_run = total_events as f64 / 3.0;
        let hb_per_run = total_heartbeats as f64 / 3.0;
        let ev_rate = events_per_run / (m.mean_ns / 1e9);
        let hb_rate = hb_per_run / (m.mean_ns / 1e9);
        println!(
            "  -> {:.0} events/run, {:.0} heartbeats/run: {:.0} events/s, \
             {:.0} heartbeats/s, {} per event",
            events_per_run,
            hb_per_run,
            ev_rate,
            hb_rate,
            fmt_ns(m.mean_ns / events_per_run)
        );
    }
}
