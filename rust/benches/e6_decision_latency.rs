//! Bench e6_decision_latency: per-heartbeat scheduling cost under the
//! batched API — one `assign()` call filling a whole heartbeat's slots vs
//! the legacy per-slot pattern (emulated as budget-1 calls, one per slot) —
//! plus the E6 scalability table. Writes `BENCH_e6.json` so the perf
//! trajectory is tracked across PRs.
//!
//!     cargo bench --bench e6_decision_latency

use std::collections::BTreeMap;

use bayes_sched::bayes::features::FailureHistory;
use bayes_sched::cluster::node::{Node, NodeId, NodeSpec};
use bayes_sched::config::json::Json;
use bayes_sched::hdfs::Namespace;
use bayes_sched::job::queue::JobTable;
use bayes_sched::report::bench::{bench, fmt_ns, Measurement};
use bayes_sched::report::experiments::{self, ExpOpts};
use bayes_sched::scheduler;
use bayes_sched::scheduler::api::{SchedEvent, SchedView, SlotBudget};
use bayes_sched::workload::generator::{generate, WorkloadConfig};

/// Map slots a heartbeat typically has to fill in this comparison.
const SLOTS: u32 = 4;

/// `BENCH_SMOKE=1` shrinks iteration counts and the E6 table so CI can
/// track the perf trajectory on every push without minutes of wall time.
fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn queue_fixture(q: usize) -> (JobTable, Namespace) {
    let mut hdfs = Namespace::new(40, 4, 1);
    let mut jobs = JobTable::new();
    let specs = generate(&WorkloadConfig {
        n_jobs: q,
        arrival_rate: 1e9, // all queued at ~t=0
        seed: 1,
        ..Default::default()
    });
    for s in specs {
        jobs.submit(s, &mut hdfs);
    }
    (jobs, hdfs)
}

/// Measure one heartbeat's scheduling cost for a queue of `q` jobs, both
/// ways. Returns (batched, per_slot) measurements.
fn heartbeat_bench(sched_name: &str, q: usize) -> (Measurement, Measurement) {
    let (jobs, hdfs) = queue_fixture(q);
    let queue = jobs.schedulable();
    assert_eq!(queue.len(), q);
    let node = Node::new(
        NodeId(0),
        NodeSpec { map_slots: SLOTS, reduce_slots: 2, ..Default::default() },
    );
    let mut sched = scheduler::by_name(sched_name, 1).unwrap();
    sched.observe(&SchedEvent::ClusterInfo { total_slots: 160 });
    let fails = FailureHistory::new();
    let (warmup, iters) = if smoke() { (5, 50) } else { (50, 1000) };

    // batched: the queue is scored once, all SLOTS slots filled in one call
    let batched =
        bench(&format!("assign/batched/{sched_name}/q{q}"), warmup, iters, |_| {
            let view = SchedView {
                jobs: &jobs,
                hdfs: &hdfs,
                queue: &queue,
                failures: &fails,
                now: 100.0,
            };
            std::hint::black_box(sched.assign(
                &view,
                &node,
                SlotBudget { maps: SLOTS, reduces: 0 },
            ));
        });
    // per-slot baseline: the legacy pattern — one decision per free slot,
    // re-scoring the queue every time
    let per_slot =
        bench(&format!("assign/per_slot/{sched_name}/q{q}"), warmup, iters, |_| {
            for _ in 0..SLOTS {
                let view = SchedView {
                    jobs: &jobs,
                    hdfs: &hdfs,
                    queue: &queue,
                    failures: &fails,
                    now: 100.0,
                };
                std::hint::black_box(sched.assign(
                    &view,
                    &node,
                    SlotBudget { maps: 1, reduces: 0 },
                ));
            }
        });
    (batched, per_slot)
}

fn main() {
    println!("== per-heartbeat cost: batched assign vs per-slot baseline ({SLOTS} map slots) ==");
    let mut results: BTreeMap<String, Json> = BTreeMap::new();
    for q in [16usize, 64, 256] {
        for sched_name in ["fifo", "fair", "capacity", "bayes"] {
            let (batched, per_slot) = heartbeat_bench(sched_name, q);
            let speedup = per_slot.mean_ns / batched.mean_ns.max(1.0);
            println!(
                "  -> {sched_name}/q{q}: batched {} vs per-slot {} ({speedup:.2}x)",
                fmt_ns(batched.mean_ns),
                fmt_ns(per_slot.mean_ns),
            );
            let mut entry = BTreeMap::new();
            entry.insert("batched_ns".to_string(), Json::Num(batched.mean_ns));
            entry.insert("per_slot_ns".to_string(), Json::Num(per_slot.mean_ns));
            entry.insert("speedup".to_string(), Json::Num(speedup));
            results.insert(format!("{sched_name}_q{q}"), Json::Obj(entry));
        }
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("e6_decision_latency".into()));
    doc.insert("slots_per_heartbeat".to_string(), Json::Num(SLOTS as f64));
    // keep each insert on one line: the bench-baseline lint reads the
    // schema straight out of this source (see LINTS.md)
    let smoke_flag = if smoke() { 1.0 } else { 0.0 };
    doc.insert("smoke".to_string(), Json::Num(smoke_flag));
    doc.insert("results".to_string(), Json::Obj(results));
    let json = Json::Obj(doc);
    match std::fs::write("BENCH_e6.json", json.to_string_pretty()) {
        Ok(()) => println!("\nwrote BENCH_e6.json"),
        Err(e) => eprintln!("\ncould not write BENCH_e6.json: {e}"),
    }

    println!("\n== E6 scalability table ==");
    let opts = ExpOpts { quick: smoke(), out_dir: Some("results".into()), ..Default::default() };
    for t in experiments::run("e6", &opts).unwrap() {
        println!("{}", t.render());
    }
}
