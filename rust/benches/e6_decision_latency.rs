//! Bench e6_decision_latency: regenerates E6 (scalability) and measures
//! the isolated scheduler decision cost vs queue length — the L3 hot-path
//! number the coordinator's throughput hinges on.
//!
//!     cargo bench --bench e6_decision_latency

use bayes_sched::cluster::node::{Node, NodeId, NodeSpec};
use bayes_sched::hdfs::Namespace;
use bayes_sched::job::queue::JobTable;
use bayes_sched::job::task::TaskKind;
use bayes_sched::report::bench::bench;
use bayes_sched::report::experiments::{self, ExpOpts};
use bayes_sched::scheduler::api::SchedView;
use bayes_sched::scheduler::{self, Scheduler};
use bayes_sched::workload::generator::{generate, WorkloadConfig};

/// Isolated decision microbenchmark: a queue of `q` schedulable jobs, one
/// idle node, measure a single select() call.
fn decision_bench(sched_name: &str, q: usize) {
    let mut hdfs = Namespace::new(40, 4, 1);
    let mut jobs = JobTable::new();
    let specs = generate(&WorkloadConfig {
        n_jobs: q,
        arrival_rate: 1e9, // all queued at ~t=0
        seed: 1,
        ..Default::default()
    });
    for s in specs {
        jobs.submit(s, &mut hdfs);
    }
    let queue = jobs.schedulable();
    assert_eq!(queue.len(), q);
    let node = Node::new(NodeId(0), NodeSpec::default());
    let mut sched = scheduler::by_name(sched_name, 1).unwrap();
    sched.on_cluster_info(160);
    bench(&format!("decision/{sched_name}/q{q}"), 100, 2000, |_| {
        let view = SchedView { jobs: &jobs, hdfs: &hdfs, queue: &queue, now: 100.0 };
        std::hint::black_box(sched.select(&view, &node, TaskKind::Map));
    });
}

fn main() {
    println!("== isolated decision latency vs queue length ==");
    for q in [16, 64, 256] {
        for sched in ["fifo", "fair", "capacity", "bayes"] {
            decision_bench(sched, q);
        }
    }

    println!("\n== E6 scalability table ==");
    let opts = ExpOpts { quick: false, out_dir: Some("results".into()) };
    for t in experiments::run("e6", &opts).unwrap() {
        println!("{}", t.render());
    }
}
