//! Bench engine_events_per_sec: the classic *hold* benchmark for event
//! queues — at a steady pending-event population, each operation pops the
//! earliest event and schedules a successor a random offset later. This is
//! exactly the drivers' steady state (every completion schedules the next
//! heartbeat/arrival), so per-hold cost is per-event engine overhead.
//!
//! Compares the production calendar-queue backend (`Engine`) against the
//! binary-heap reference (`HeapEngine`) across pending sizes, and writes
//! `BENCH_engine.json` so the perf trajectory is tracked across PRs.
//!
//!     cargo bench --bench engine_events_per_sec

use std::collections::BTreeMap;

use bayes_sched::cluster::node::NodeId;
use bayes_sched::config::json::Json;
use bayes_sched::obs::Registry;
use bayes_sched::report::bench::{bench, fmt_ns, Measurement};
use bayes_sched::sim::engine::EngineImpl;
use bayes_sched::sim::{Event, EventQueue, Pcg};

/// Hold operations per timed iteration (per-event cost = mean_ns / this).
const HOLDS_PER_ITER: usize = 1000;

/// `BENCH_SMOKE=1` shrinks pending sizes and iteration counts so CI can
/// track the trajectory on every push.
fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Measure the hold loop on one backend at a steady `pending` population.
fn hold_bench<Q: EventQueue + Default>(
    label: &str,
    pending: usize,
    warmup: usize,
    iters: usize,
) -> Measurement {
    let mut e: EngineImpl<Q> = EngineImpl::new();
    let mut rng = Pcg::seeded(7);
    // prefill with the same spread the holds maintain (~1.5s window), so
    // the measured regime is the steady state, not a cold start
    for i in 0..pending {
        e.schedule(rng.range_f64(0.0, 1.5), Event::Heartbeat(NodeId(i as u32)));
    }
    bench(label, warmup, iters, move |_| {
        for _ in 0..HOLDS_PER_ITER {
            // the population is constant: every pop is followed by a push
            let (t, ev) = e.pop().unwrap();
            e.schedule(t + rng.range_f64(0.5, 1.5), ev);
        }
        std::hint::black_box(e.now());
    })
}

/// The hold loop on the calendar queue with the obs record path live: one
/// counter bump plus one histogram record per hold, the same shape the
/// instrumented engine/driver hot paths pay. The delta against the plain
/// calendar arm is the observability overhead CI bounds (<5%).
fn obs_hold_bench(pending: usize, warmup: usize, iters: usize) -> Measurement {
    let mut e: EngineImpl<bayes_sched::sim::CalendarQueue> = EngineImpl::new();
    let mut rng = Pcg::seeded(7);
    for i in 0..pending {
        e.schedule(rng.range_f64(0.0, 1.5), Event::Heartbeat(NodeId(i as u32)));
    }
    let registry = Registry::new();
    registry.set_enabled(true);
    let dispatched = registry.counter("engine_events_dispatched");
    let hold_nanos = registry.histogram("bench_hold_nanos");
    bench(&format!("hold/calendar+obs/{pending}"), warmup, iters, move |_| {
        for _ in 0..HOLDS_PER_ITER {
            let (t, ev) = e.pop().unwrap();
            dispatched.inc();
            hold_nanos.record(t.to_bits() & 0xFFFF);
            e.schedule(t + rng.range_f64(0.5, 1.5), ev);
        }
        std::hint::black_box(e.now());
    })
}

fn main() {
    println!("== engine hold throughput: calendar queue vs binary heap ==");
    let sizes: &[usize] = if smoke() {
        &[1_000, 10_000]
    } else {
        &[1_000, 10_000, 100_000, 500_000]
    };
    let (warmup, iters) = if smoke() { (3, 30) } else { (10, 200) };
    let mut results: BTreeMap<String, Json> = BTreeMap::new();
    for &n in sizes {
        let heap = hold_bench::<bayes_sched::sim::engine::HeapQueue>(
            &format!("hold/heap/{n}"),
            n,
            warmup,
            iters,
        );
        let cal = hold_bench::<bayes_sched::sim::CalendarQueue>(
            &format!("hold/calendar/{n}"),
            n,
            warmup,
            iters,
        );
        let obs = obs_hold_bench(n, warmup, iters);
        let heap_ns = heap.mean_ns / HOLDS_PER_ITER as f64;
        let cal_ns = cal.mean_ns / HOLDS_PER_ITER as f64;
        let obs_ns = obs.mean_ns / HOLDS_PER_ITER as f64;
        let speedup = heap_ns / cal_ns.max(1e-9);
        let obs_overhead_pct = (obs_ns - cal_ns) / cal_ns.max(1e-9) * 100.0;
        println!(
            "  -> pending {n:>7}: heap {}/ev vs calendar {}/ev ({speedup:.2}x), \
             +obs {}/ev ({obs_overhead_pct:.1}% overhead)",
            fmt_ns(heap_ns),
            fmt_ns(cal_ns),
            fmt_ns(obs_ns),
        );
        let mut entry = BTreeMap::new();
        entry.insert("heap_ns".to_string(), Json::Num(heap_ns));
        entry.insert("calendar_ns".to_string(), Json::Num(cal_ns));
        entry.insert("speedup".to_string(), Json::Num(speedup));
        entry.insert("obs_ns".to_string(), Json::Num(obs_ns));
        entry.insert("obs_overhead_pct".to_string(), Json::Num(obs_overhead_pct));
        results.insert(format!("pending_{n}"), Json::Obj(entry));
    }
    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("engine_events_per_sec".into()));
    doc.insert("holds_per_iter".to_string(), Json::Num(HOLDS_PER_ITER as f64));
    // keep each insert on one line: the bench-baseline lint reads the
    // schema straight out of this source (see LINTS.md)
    let smoke_flag = if smoke() { 1.0 } else { 0.0 };
    doc.insert("smoke".to_string(), Json::Num(smoke_flag));
    doc.insert("results".to_string(), Json::Obj(results));
    let json = Json::Obj(doc);
    match std::fs::write("BENCH_engine.json", json.to_string_pretty()) {
        Ok(()) => println!("\nwrote BENCH_engine.json"),
        Err(e) => eprintln!("\ncould not write BENCH_engine.json: {e}"),
    }
}
