//! Bench p1_classify: the classifier hot path — XLA/PJRT artifact
//! execution vs the pure-rust NaiveBayes, across batch sizes, plus the
//! update (feedback flush) path. This is the L1/L2 perf deliverable's
//! measurement harness (EXPERIMENTS.md §Perf).
//!
//!     cargo bench --bench p1_classify

use bayes_sched::bayes::classifier::{Classifier, Label, NaiveBayes, MAX_BATCH};
use bayes_sched::bayes::features::{FeatureVec, N_FEATURES};
use bayes_sched::report::bench::bench;
use bayes_sched::sim::rng::Pcg;

fn random_fv(rng: &mut Pcg) -> FeatureVec {
    let mut fv = [0u8; N_FEATURES];
    for b in fv.iter_mut() {
        *b = rng.below(10) as u8;
    }
    fv
}

fn train(c: &mut dyn Classifier, rng: &mut Pcg, n: usize) {
    for _ in 0..n {
        let fv = random_fv(rng);
        let label = if fv[0] >= 5 { Label::Bad } else { Label::Good };
        c.observe(fv, label);
    }
    c.flush();
}

fn main() {
    let mut rng = Pcg::seeded(1);
    let feats: Vec<FeatureVec> = (0..256).map(|_| random_fv(&mut rng)).collect();
    let utility: Vec<f32> = (0..256).map(|_| rng.f64() as f32 * 5.0).collect();

    println!("== classify: pure-rust NaiveBayes ==");
    let mut nb = NaiveBayes::new(1.0);
    train(&mut nb, &mut rng, 500);
    for n in [64usize, 128, 256] {
        bench(&format!("classify/rust/n{n}"), 100, 5000, |_| {
            std::hint::black_box(nb.classify(&feats[..n], &utility[..n]));
        });
    }

    println!("\n== update flush: pure-rust NaiveBayes (batch=128) ==");
    bench("update/rust/batch128", 10, 500, |_| {
        for i in 0..MAX_BATCH {
            nb.observe(feats[i % feats.len()], Label::Good);
        }
        nb.flush();
    });

    xla_benches(&feats, &utility, &mut rng);
}

#[cfg(feature = "xla-runtime")]
fn xla_benches(feats: &[FeatureVec], utility: &[f32], rng: &mut Pcg) {
    use bayes_sched::runtime::XlaClassifier;
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("\nartifacts/ missing — skipping XLA benches (run `make artifacts`)");
        return;
    }
    println!("\n== classify: XLA/PJRT artifact (padded to 256) ==");
    let mut xla = XlaClassifier::load(&dir, 1.0).expect("load artifacts");
    train(&mut xla, rng, 500);
    for n in [64usize, 128, 256] {
        bench(&format!("classify/xla/n{n}"), 20, 200, |_| {
            std::hint::black_box(xla.classify(&feats[..n], &utility[..n]));
        });
    }

    println!("\n== breakdown: host->device upload cost of per-call inputs ==");
    {
        use bayes_sched::runtime::Runtime;
        let rt = Runtime::load(&dir).expect("runtime");
        let c = rt.consts;
        let feats_i32 = vec![0i32; c.max_jobs * c.n_features];
        let utility_f = vec![1.0f32; c.max_jobs];
        let mask_f = vec![1.0f32; c.max_jobs];
        bench("classify/xla/inputs_upload_only", 20, 500, |_| {
            std::hint::black_box(
                rt.upload_inputs_probe(&feats_i32, &utility_f, &mask_f).unwrap(),
            );
        });
    }

    println!("\n== update flush: XLA/PJRT artifact (batch=128) ==");
    bench("update/xla/batch128", 3, 50, |_| {
        for i in 0..MAX_BATCH {
            xla.observe(feats[i % feats.len()], Label::Good);
        }
        xla.flush();
    });
}

#[cfg(not(feature = "xla-runtime"))]
fn xla_benches(_feats: &[FeatureVec], _utility: &[f32], _rng: &mut Pcg) {
    println!("\nbuilt without the `xla-runtime` feature — skipping XLA benches");
}
