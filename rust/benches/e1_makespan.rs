//! Bench e1_makespan: regenerates the E1/E2 efficiency+stability tables
//! (DESIGN.md §4) end-to-end and times whole simulation runs per
//! scheduler — the "one bench per paper table" target for the headline
//! claim.
//!
//!     cargo bench --bench e1_makespan

use bayes_sched::coordinator::builder::RunConfig;
use bayes_sched::report::bench::bench;
use bayes_sched::report::experiments::common::run_once;
use bayes_sched::report::experiments::{self, ExpOpts};
use bayes_sched::workload::generator::WorkloadConfig;

fn main() {
    println!("== simulation wall time per scheduler (E1 configuration) ==");
    for sched in ["fifo", "fair", "capacity", "bayes"] {
        bench(&format!("e1_run/{sched}/40n_200j"), 1, 5, |i| {
            let cfg = RunConfig {
                scheduler: sched.into(),
                n_nodes: 40,
                n_racks: 4,
                workload: WorkloadConfig {
                    n_jobs: 200,
                    arrival_rate: 0.5,
                    seed: 1 + i as u64,
                    ..Default::default()
                },
                ..Default::default()
            };
            std::hint::black_box(run_once(&cfg));
        });
    }

    println!("\n== E1 efficiency table ==");
    let opts = ExpOpts { quick: false, out_dir: Some("results".into()), ..Default::default() };
    for t in experiments::run("e1", &opts).unwrap() {
        println!("{}", t.render());
    }
    println!("== E2 stability table ==");
    for t in experiments::run("e2", &opts).unwrap() {
        println!("{}", t.render());
    }
}
