//! Bench trace_ingest_throughput: decode a generated trace back into
//! `JobSpec`s three ways — the streaming pull-parser reader over JSONL,
//! the same reader over the array format, and the legacy path that
//! materializes the whole document as a `Json` tree first. Reports
//! specs/sec and bytes/sec per arm and writes `BENCH_ingest.json` so the
//! ingest trajectory is tracked across PRs; the streaming arms must not
//! fall behind the tree arm (that would mean the pull parser stopped
//! paying for itself).
//!
//!     cargo bench --bench trace_ingest_throughput

use std::collections::BTreeMap;

use bayes_sched::config::json::Json;
use bayes_sched::report::bench::{bench, Measurement};
use bayes_sched::workload::generator::{stream, WorkloadConfig};
use bayes_sched::workload::trace::{TraceFormat, TraceReader, TraceWriter};

/// `BENCH_SMOKE=1` shrinks the trace and iteration counts so CI can
/// track the trajectory on every push.
fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Serialize the workload once into an in-memory trace.
fn encode(n_specs: usize, format: TraceFormat) -> Vec<u8> {
    let cfg = WorkloadConfig { n_jobs: n_specs, seed: 42, ..Default::default() };
    let mut buf: Vec<u8> = Vec::new();
    let mut w = TraceWriter::new(&mut buf, format);
    for spec in stream(&cfg) {
        w.write_spec(&spec).unwrap();
    }
    w.finish().unwrap();
    buf
}

/// Decode every spec with the streaming reader; returns the spec count.
fn stream_decode(bytes: &[u8]) -> u64 {
    let mut n = 0u64;
    for spec in TraceReader::new(bytes).unwrap() {
        std::hint::black_box(&spec.unwrap().name);
        n += 1;
    }
    n
}

/// The legacy shape: materialize the whole array as a `Json` tree, then
/// walk it touching each record's fields (what `trace::load` did before
/// the pull parser).
fn tree_decode(text: &str) -> u64 {
    let doc = Json::parse(text).unwrap();
    let arr = doc.as_arr().unwrap();
    let mut n = 0u64;
    for rec in arr {
        std::hint::black_box(rec.get("name").and_then(Json::as_str).unwrap());
        std::hint::black_box(rec.get("submit_time").and_then(Json::as_f64).unwrap());
        n += 1;
    }
    n
}

fn rates(m: &Measurement, n_specs: usize, bytes: usize) -> (f64, f64) {
    let secs = m.mean_ns / 1e9;
    (n_specs as f64 / secs, bytes as f64 / secs)
}

fn main() {
    println!("== trace ingest throughput: streaming pull parser vs Json tree ==");
    let n_specs: usize = if smoke() { 2_000 } else { 50_000 };
    let (warmup, iters) = if smoke() { (1, 5) } else { (3, 30) };

    let jsonl = encode(n_specs, TraceFormat::Jsonl);
    let array = encode(n_specs, TraceFormat::Array);
    let array_text = String::from_utf8(array.clone()).unwrap();
    assert_eq!(stream_decode(&jsonl), n_specs as u64);
    assert_eq!(stream_decode(&array), n_specs as u64);
    assert_eq!(tree_decode(&array_text), n_specs as u64);

    let mut results: BTreeMap<String, Json> = BTreeMap::new();
    let arms: [(&str, Box<dyn FnMut() -> u64>, usize); 3] = [
        ("jsonl_stream", Box::new(|| stream_decode(&jsonl)), jsonl.len()),
        ("array_stream", Box::new(|| stream_decode(&array)), array.len()),
        ("array_tree", Box::new(|| tree_decode(&array_text)), array.len()),
    ];
    for (label, mut decode, bytes) in arms {
        let m = bench(&format!("ingest/{label}/{n_specs}"), warmup, iters, |_| {
            std::hint::black_box(decode());
        });
        let (specs_per_sec, bytes_per_sec) = rates(&m, n_specs, bytes);
        println!(
            "  -> {label:>12}: {:.0} specs/s, {:.1} MB/s",
            specs_per_sec,
            bytes_per_sec / 1e6
        );
        let mut entry = BTreeMap::new();
        entry.insert("mean_ns".to_string(), Json::Num(m.mean_ns));
        entry.insert("specs_per_sec".to_string(), Json::Num(specs_per_sec));
        entry.insert("bytes_per_sec".to_string(), Json::Num(bytes_per_sec));
        results.insert(label.to_string(), Json::Obj(entry));
    }

    let mut doc = BTreeMap::new();
    doc.insert("bench".to_string(), Json::Str("trace_ingest_throughput".into()));
    doc.insert("n_specs".to_string(), Json::Num(n_specs as f64));
    // keep each insert on one line: the bench-baseline lint reads the
    // schema straight out of this source (see LINTS.md)
    let smoke_flag = if smoke() { 1.0 } else { 0.0 };
    doc.insert("smoke".to_string(), Json::Num(smoke_flag));
    doc.insert("results".to_string(), Json::Obj(results));
    let json = Json::Obj(doc);
    match std::fs::write("BENCH_ingest.json", json.to_string_pretty()) {
        Ok(()) => println!("\nwrote BENCH_ingest.json"),
        Err(e) => eprintln!("\ncould not write BENCH_ingest.json: {e}"),
    }
}
