//! Integration over the simulation substrate: arrival timing, contention
//! economics, OOM recovery, heterogeneity and trace replay — behaviours
//! that only emerge with all substrates composed.

use bayes_sched::cluster::node::NodeSpec;
use bayes_sched::cluster::resources::Resources;
use bayes_sched::cluster::Cluster;
use bayes_sched::coordinator::jobtracker::{JobTracker, TrackerConfig};
use bayes_sched::job::profile::JobClass;
use bayes_sched::scheduler;
use bayes_sched::workload::generator::{generate, Mix, WorkloadConfig};
use bayes_sched::workload::trace;

fn tracker(
    cluster: Cluster,
    sched: &str,
    wl: &WorkloadConfig,
) -> JobTracker {
    JobTracker::new(
        cluster,
        scheduler::by_name(sched, wl.seed).unwrap(),
        generate(wl),
        wl.seed,
        TrackerConfig::default(),
    )
}

#[test]
fn jobs_never_launch_before_submit() {
    let wl = WorkloadConfig { n_jobs: 40, arrival_rate: 0.3, seed: 11, ..Default::default() };
    let mut jt = tracker(Cluster::homogeneous(6, 2), "fifo", &wl);
    jt.run();
    for job in jt.jobs.iter() {
        let fl = job.first_launch.expect("job never launched");
        assert!(
            fl >= job.spec.submit_time,
            "{} launched at {fl} before submit {}",
            job.id,
            job.spec.submit_time
        );
    }
}

#[test]
fn makespan_bounded_below_by_critical_path() {
    // a single job cannot finish faster than its longest map + longest
    // reduce at full speed
    let wl = WorkloadConfig { n_jobs: 1, seed: 12, ..Default::default() };
    let specs = generate(&wl);
    let longest_map = specs[0].map_works.iter().cloned().fold(0.0, f64::max);
    let longest_red = specs[0].reduce_works.iter().cloned().fold(0.0, f64::max);
    let mut jt = JobTracker::new(
        Cluster::homogeneous(8, 2),
        scheduler::by_name("fifo", 12).unwrap(),
        specs,
        12,
        TrackerConfig::default(),
    );
    jt.run();
    let lat = jt.metrics.latencies()[0];
    assert!(
        lat >= longest_map + longest_red - 1e-9,
        "latency {lat} beats critical path {}",
        longest_map + longest_red
    );
}

#[test]
fn more_nodes_never_hurt_much() {
    // same workload on 4 vs 16 nodes: bigger cluster should be distinctly
    // faster under load
    let wl = WorkloadConfig { n_jobs: 60, arrival_rate: 2.0, seed: 13, ..Default::default() };
    let mut small = tracker(Cluster::homogeneous(4, 2), "fifo", &wl);
    small.run();
    let mut big = tracker(Cluster::homogeneous(16, 4), "fifo", &wl);
    big.run();
    assert!(
        big.metrics.makespan < small.metrics.makespan,
        "big {} vs small {}",
        big.metrics.makespan,
        small.metrics.makespan
    );
}

#[test]
fn mem_heavy_overload_causes_ooms_and_they_recover() {
    // mem-heavy-only workload on few nodes with generous slots -> OOMs,
    // but every task must still finish eventually
    let cluster = Cluster::with_specs(
        (0..3)
            .map(|_| NodeSpec { map_slots: 4, reduce_slots: 2, ..Default::default() })
            .collect(),
        1,
    );
    let wl = WorkloadConfig {
        n_jobs: 20,
        arrival_rate: 2.0,
        mix: Mix::only(JobClass::MemHeavy),
        seed: 14,
        ..Default::default()
    };
    let mut jt = tracker(cluster, "fifo", &wl);
    jt.run();
    // every job terminates: completed or killed after max attempts
    assert!(jt.jobs.all_complete());
    assert!(jt.metrics.oom_kills > 0, "expected OOM kills in this workload");
    assert_eq!(jt.jobs.failed_count() as u64, jt.metrics.failed_jobs);
    assert_eq!(
        jt.metrics.completed_jobs() + jt.jobs.failed_count(),
        jt.jobs.len()
    );
    // nodes fully drained
    for n in &jt.cluster.nodes {
        assert!(n.running().is_empty());
    }
}

#[test]
fn faster_nodes_finish_work_sooner() {
    let wl = WorkloadConfig { n_jobs: 30, arrival_rate: 1.0, seed: 15, ..Default::default() };
    let slow_cluster = Cluster::with_specs(
        (0..6).map(|_| NodeSpec { speed: 0.5, ..Default::default() }).collect(),
        2,
    );
    let fast_cluster = Cluster::with_specs(
        (0..6).map(|_| NodeSpec { speed: 2.0, ..Default::default() }).collect(),
        2,
    );
    let mut slow = tracker(slow_cluster, "fifo", &wl);
    slow.run();
    let mut fast = tracker(fast_cluster, "fifo", &wl);
    fast.run();
    assert!(fast.metrics.makespan < slow.metrics.makespan);
}

#[test]
fn capacity_scaling_with_larger_capacity_nodes() {
    // nodes with double capacity absorb the same demand with less overload
    let wl = WorkloadConfig {
        n_jobs: 30,
        arrival_rate: 1.0,
        mix: Mix::only(JobClass::CpuHeavy),
        seed: 16,
        ..Default::default()
    };
    let std_cluster = Cluster::homogeneous(6, 2);
    let big_cluster = Cluster::with_specs(
        (0..6)
            .map(|_| NodeSpec { capacity: Resources::splat(2.0), ..Default::default() })
            .collect(),
        2,
    );
    let mut std_run = tracker(std_cluster, "fifo", &wl);
    std_run.run();
    let mut big_run = tracker(big_cluster, "fifo", &wl);
    big_run.run();
    assert!(big_run.metrics.overload_seconds < std_run.metrics.overload_seconds);
}

#[test]
fn trace_replay_reproduces_run_exactly() {
    let wl = WorkloadConfig { n_jobs: 25, seed: 17, ..Default::default() };
    let specs = generate(&wl);
    let path = std::env::temp_dir().join("bayes_sched_integration_trace.json");
    trace::save(&specs, &path).unwrap();
    let loaded = trace::load(&path).unwrap();

    let run = |specs: Vec<bayes_sched::job::job::JobSpec>| {
        let mut jt = JobTracker::new(
            Cluster::homogeneous(5, 2),
            scheduler::by_name("bayes", 17).unwrap(),
            specs,
            17,
            TrackerConfig::default(),
        );
        jt.run();
        (jt.metrics.makespan, jt.metrics.latencies())
    };
    assert_eq!(run(specs), run(loaded));
}

#[test]
fn heartbeat_interval_affects_allocation_granularity() {
    let wl = WorkloadConfig { n_jobs: 20, arrival_rate: 1.0, seed: 18, ..Default::default() };
    let run = |interval: f64| {
        let mut cfg = TrackerConfig::default();
        cfg.heartbeat.interval = interval;
        let mut jt = JobTracker::new(
            Cluster::homogeneous(4, 2),
            scheduler::by_name("fifo", 18).unwrap(),
            generate(&wl),
            18,
            cfg,
        );
        jt.run();
        (jt.metrics.heartbeats, jt.metrics.makespan)
    };
    let (hb_fast, mk_fast) = run(1.0);
    let (hb_slow, mk_slow) = run(10.0);
    assert!(hb_fast > hb_slow * 2);
    // coarser heartbeats waste slot time -> should not be faster
    assert!(mk_slow >= mk_fast * 0.95, "slow {mk_slow} fast {mk_fast}");
}

#[test]
fn node_failures_lose_work_but_jobs_still_finish() {
    use bayes_sched::coordinator::jobtracker::FailureConfig;
    let wl = WorkloadConfig { n_jobs: 25, arrival_rate: 0.5, seed: 41, ..Default::default() };
    let mut cfg = TrackerConfig::default();
    cfg.failures = FailureConfig { mtbf: Some(300.0), mttr: 60.0 };
    let mut jt = JobTracker::new(
        Cluster::homogeneous(8, 2),
        scheduler::by_name("fifo", 41).unwrap(),
        generate(&wl),
        41,
        cfg,
    );
    jt.run();
    assert!(jt.metrics.node_failures > 0, "no failures injected");
    assert!(jt.jobs.all_complete(), "failures stalled the cluster");
    // failures force re-runs: some wasted attempts expected
    assert!(jt.metrics.wasted_attempts() > 0);
    for n in &jt.cluster.nodes {
        assert!(n.running().is_empty());
    }
}

#[test]
fn failures_are_deterministic_per_seed() {
    use bayes_sched::coordinator::jobtracker::FailureConfig;
    let wl = WorkloadConfig { n_jobs: 15, seed: 42, ..Default::default() };
    let run = || {
        let mut cfg = TrackerConfig::default();
        cfg.failures = FailureConfig { mtbf: Some(200.0), mttr: 30.0 };
        let mut jt = JobTracker::new(
            Cluster::homogeneous(6, 2),
            scheduler::by_name("bayes", 42).unwrap(),
            generate(&wl),
            42,
            cfg,
        );
        jt.run();
        (jt.metrics.node_failures, jt.metrics.makespan, jt.engine.processed())
    };
    assert_eq!(run(), run());
}

#[test]
fn timeline_sampling_covers_the_run() {
    let wl = WorkloadConfig { n_jobs: 15, seed: 43, ..Default::default() };
    let mut cfg = TrackerConfig::default();
    cfg.timeline_interval = 20.0;
    let mut jt = JobTracker::new(
        Cluster::homogeneous(6, 2),
        scheduler::by_name("fifo", 43).unwrap(),
        generate(&wl),
        43,
        cfg,
    );
    jt.run();
    let tl = jt.metrics.timeline.samples();
    assert!(tl.len() >= 3, "too few samples: {}", tl.len());
    // monotone time, ~20s apart (stride 1: the run is far below the cap)
    for w in tl.windows(2) {
        assert!(w[1].time > w[0].time);
        assert!((w[1].time - w[0].time - 20.0).abs() < 1e-6);
    }
    // utilization was non-zero at some point
    assert!(tl.iter().any(|s| s.mean_bottleneck_util > 0.1));
    assert!(tl.iter().all(|s| s.alive_nodes == 6));
}

#[test]
fn warm_start_model_roundtrip_through_persistence() {
    use bayes_sched::bayes::classifier::{Classifier, NaiveBayes};
    use bayes_sched::bayes::persist;
    use bayes_sched::scheduler::BayesScheduler;
    let wl = WorkloadConfig { n_jobs: 40, arrival_rate: 1.0, seed: 44, ..Default::default() };
    // run once, export the model via the Scheduler hook
    let mut jt = JobTracker::new(
        Cluster::homogeneous(6, 2),
        Box::new(BayesScheduler::new(NaiveBayes::new(1.0))),
        generate(&wl),
        44,
        TrackerConfig::default(),
    );
    jt.run();
    let model_json = jt.scheduler.export_model().expect("bayes exports a model");
    let nb = persist::from_json(&model_json).unwrap();
    let [good, bad] = nb.class_counts();
    assert!(good + bad > 0.0, "model absorbed no feedback");
    // warm-started run completes and re-exports a strictly bigger model
    let mut jt2 = JobTracker::new(
        Cluster::homogeneous(6, 2),
        Box::new(BayesScheduler::new(nb)),
        generate(&wl),
        44,
        TrackerConfig::default(),
    );
    jt2.run();
    let nb2 = persist::from_json(&jt2.scheduler.export_model().unwrap()).unwrap();
    let [g2, b2] = nb2.class_counts();
    assert!(g2 + b2 > good + bad);
}
