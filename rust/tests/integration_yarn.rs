//! YARN-mode integration (paper §2 + E10): policies complete workloads,
//! misdeclaration hurts the fit-only policies more than the learner, and
//! the declared-resource bookkeeping stays consistent.

use bayes_sched::cluster::Cluster;
use bayes_sched::workload::generator::{generate, Mix, WorkloadConfig};
use bayes_sched::yarn::{yarn_policy_by_name, ResourceManager, YarnConfig};

fn run(policy: &str, wl: &WorkloadConfig, nodes: u32) -> ResourceManager {
    let mut rm = ResourceManager::new(
        Cluster::homogeneous(nodes, 2),
        yarn_policy_by_name(policy, 1.0).unwrap(),
        generate(wl),
        wl.seed,
        YarnConfig::default(),
    );
    rm.run();
    rm
}

#[test]
fn workload_completes_under_all_policies() {
    let wl = WorkloadConfig { n_jobs: 30, arrival_rate: 1.0, seed: 31, ..Default::default() };
    for p in ["yarn-fifo", "yarn-fair", "yarn-bayes"] {
        let rm = run(p, &wl, 8);
        assert!(rm.jobs.all_complete(), "{p}");
        // success + max-attempts kills account for every job
        assert_eq!(
            rm.metrics.completed_jobs() + rm.jobs.failed_count(),
            30,
            "{p}"
        );
        assert!(rm.metrics.completed_jobs() >= 24, "{p} failed too many jobs");
    }
}

#[test]
fn misdeclaration_produces_overloads_under_fit_only_policy() {
    // strict declared-fit can still overload because actual > declared
    let wl = WorkloadConfig {
        n_jobs: 60,
        arrival_rate: 1.5,
        mix: Mix::cpu_fraction(0.6),
        seed: 32,
        ..Default::default()
    };
    let rm = run("yarn-fifo", &wl, 6);
    assert!(
        rm.metrics.feedback[1] > 0,
        "expected overload feedback despite fit checks"
    );
}

#[test]
fn bayes_policy_learns_to_cut_overloads() {
    let wl = WorkloadConfig {
        n_jobs: 120,
        arrival_rate: 1.2,
        mix: Mix::cpu_fraction(0.6),
        seed: 33,
        ..Default::default()
    };
    let fifo = run("yarn-fifo", &wl, 8);
    let bayes = run("yarn-bayes", &wl, 8);
    assert!(
        bayes.metrics.overload_rate() <= fifo.metrics.overload_rate(),
        "yarn-bayes {} vs yarn-fifo {}",
        bayes.metrics.overload_rate(),
        fifo.metrics.overload_rate()
    );
}

#[test]
fn yarn_mode_deterministic() {
    let wl = WorkloadConfig { n_jobs: 25, seed: 34, ..Default::default() };
    let a = run("yarn-bayes", &wl, 5);
    let b = run("yarn-bayes", &wl, 5);
    assert_eq!(a.metrics.makespan, b.metrics.makespan);
    assert_eq!(a.metrics.latencies(), b.metrics.latencies());
}

#[test]
fn fair_policy_balances_concurrent_apps() {
    // two simultaneous long jobs: yarn-fair should interleave containers
    let wl = WorkloadConfig { n_jobs: 2, arrival_rate: 100.0, seed: 35, ..Default::default() };
    let rm = run("yarn-fair", &wl, 4);
    assert!(rm.jobs.all_complete());
    let lats = rm.metrics.latencies();
    assert_eq!(lats.len() + rm.jobs.failed_count(), 2);
    if lats.len() == 2 {
        // both jobs overlap in execution: neither waits entirely
        let spread = (lats[0] - lats[1]).abs();
        assert!(
            spread < lats[0].max(lats[1]),
            "fair policy serialized the apps: {lats:?}"
        );
    }
}
