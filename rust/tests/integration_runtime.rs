//! Runtime integration: load the AOT artifacts through PJRT and check (a)
//! raw execution works, (b) the XLA classifier and the pure-rust
//! NaiveBayes agree to f32 tolerance on identical feedback streams —
//! the differential test that pins the artifact semantics.
//!
//! Requires `make artifacts` (skipped with a message otherwise) and a build
//! with `--features xla-runtime` (the whole file is compiled out without it).
#![cfg(feature = "xla-runtime")]

use std::path::PathBuf;

use bayes_sched::bayes::classifier::{Classifier, Label, NaiveBayes, MAX_BATCH};
use bayes_sched::bayes::features::{FeatureVec, N_FEATURES};
use bayes_sched::runtime::{Runtime, XlaClassifier};
use bayes_sched::sim::rng::Pcg;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
                return;
            }
        }
    };
}

fn random_fv(rng: &mut Pcg) -> FeatureVec {
    let mut fv = [0u8; N_FEATURES];
    for b in fv.iter_mut() {
        *b = rng.below(10) as u8;
    }
    fv
}

#[test]
fn classify_artifact_executes() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).expect("runtime load");
    let c = rt.consts;
    let log_prior = vec![(0.5f32).ln(); 2];
    let log_lik = vec![(0.1f32).ln(); c.n_classes * c.feature_dim];
    let feats = vec![0i32; c.max_jobs * c.n_features];
    let utility = vec![1.0f32; c.max_jobs];
    let mut mask = vec![0.0f32; c.max_jobs];
    mask[0] = 1.0;
    mask[3] = 1.0;
    let out = rt
        .classify_raw(&log_prior, &log_lik, &feats, &utility, &mask)
        .expect("classify");
    assert_eq!(out.p_good.len(), c.max_jobs);
    // uniform tables -> posterior exactly 0.5
    assert!((out.p_good[0] - 0.5).abs() < 1e-6);
    // masked-out slots can never win
    assert!(out.best == 0 || out.best == 3, "best={}", out.best);
    assert!(out.score[1] < -1e29);
}

#[test]
fn update_artifact_accumulates_counts() {
    let dir = require_artifacts!();
    let rt = Runtime::load(&dir).expect("runtime load");
    let c = rt.consts;
    let counts = vec![0.0f32; c.n_classes * c.feature_dim];
    let class_counts = vec![0.0f32; c.n_classes];
    let mut feats = vec![0i32; c.max_batch * c.n_features];
    let mut labels = vec![0i32; c.max_batch];
    let mut mask = vec![0.0f32; c.max_batch];
    // 3 real samples: two bad with bin 9, one good with bin 2
    for (i, (bin, lab)) in [(9, 1), (9, 1), (2, 0)].iter().enumerate() {
        for j in 0..c.n_features {
            feats[i * c.n_features + j] = *bin;
        }
        labels[i] = *lab;
        mask[i] = 1.0;
    }
    let out = rt
        .update_raw(&counts, &class_counts, &feats, &labels, &mask, 1.0)
        .expect("update");
    assert_eq!(out.class_counts, vec![1.0, 2.0]);
    let total: f32 = out.counts.iter().sum();
    assert_eq!(total, 3.0 * c.n_features as f32);
    // log tables finite
    assert!(out.log_prior.iter().all(|x| x.is_finite()));
    assert!(out.log_lik.iter().all(|x| x.is_finite()));
}

#[test]
fn xla_classifier_matches_rust_naive_bayes() {
    let dir = require_artifacts!();
    let mut xla = XlaClassifier::load(&dir, 1.0).expect("classifier load");
    let mut nb = NaiveBayes::new(1.0);
    let mut rng = Pcg::seeded(42);

    // identical feedback streams, flushed at identical points
    for round in 0..4 {
        for _ in 0..100 {
            let fv = random_fv(&mut rng);
            // correlate label with feature 0 plus noise
            let label = if fv[0] >= 5 && rng.chance(0.8) {
                Label::Bad
            } else {
                Label::Good
            };
            xla.observe(fv, label);
            nb.observe(fv, label);
        }
        xla.flush();
        nb.flush();

        // state identical (integer counts in f32)
        let (xc, xcc) = xla.state();
        let (rc, rcc) = nb.state();
        assert_eq!(xcc, rcc, "class counts diverged in round {round}");
        assert_eq!(xc, rc, "counts diverged in round {round}");

        // classification agrees to tolerance
        let feats: Vec<FeatureVec> = (0..64).map(|_| random_fv(&mut rng)).collect();
        let utility: Vec<f32> = (0..64).map(|_| rng.f64() as f32 * 5.0).collect();
        let a = xla.classify(&feats, &utility);
        let b = nb.classify(&feats, &utility);
        for i in 0..feats.len() {
            assert!(
                (a.p_good[i] - b.p_good[i]).abs() < 1e-4,
                "round {round} p_good[{i}]: xla={} rust={}",
                a.p_good[i],
                b.p_good[i]
            );
        }
        assert_eq!(a.best, b.best, "round {round} best index diverged");
    }
}

#[test]
fn xla_classifier_handles_oversized_feedback_burst() {
    let dir = require_artifacts!();
    let mut xla = XlaClassifier::load(&dir, 1.0).expect("classifier load");
    let mut rng = Pcg::seeded(7);
    // 2.5x MAX_BATCH pending at once -> multiple update executions
    for _ in 0..(MAX_BATCH * 5 / 2) {
        xla.observe(random_fv(&mut rng), Label::Good);
    }
    xla.flush();
    let [good, bad] = xla.class_counts();
    assert_eq!(good as usize, MAX_BATCH * 5 / 2);
    assert_eq!(bad, 0.0);
}

#[test]
fn bayes_xla_scheduler_runs_end_to_end() {
    let dir = require_artifacts!();
    use bayes_sched::coordinator::{build_tracker, RunConfig};
    use bayes_sched::workload::generator::WorkloadConfig;
    let cfg = RunConfig {
        scheduler: "bayes-xla".into(),
        n_nodes: 4,
        n_racks: 2,
        workload: WorkloadConfig { n_jobs: 8, ..Default::default() },
        artifacts_dir: Some(dir),
        ..Default::default()
    };
    let mut jt = build_tracker(&cfg).unwrap();
    jt.run();
    assert!(jt.jobs.all_complete());
    assert!(jt.metrics.makespan > 0.0);
}
