//! Obs integration: a real run with every exporter on. Pins the
//! acceptance invariants end to end — the chrome trace's instant counts
//! match the `SchedEvent` totals the counters saw, the Prometheus
//! snapshot parses, the JSONL stream round-trips against it, sampling is
//! deterministic, enabling obs (windowed or not) leaves the simulation
//! bit-identical, the window series is deterministic and sums back to
//! the final counters, kind collisions never corrupt an export, and the
//! E10 sweep writes per-cell suffixed files instead of clobbering.

use std::path::{Path, PathBuf};

use bayes_sched::cluster::Cluster;
use bayes_sched::coordinator::builder::{build_tracker_with, RunConfig};
use bayes_sched::obs::export::{
    chrome_event_counts, parse_jsonl, parse_prometheus, to_jsonl, to_prometheus,
};
use bayes_sched::obs::timeseries::counter_total;
use bayes_sched::obs::{ObsOptions, Registry, Tracer};
use bayes_sched::report::experiments::e10::e10;
use bayes_sched::report::experiments::ExpOpts;
use bayes_sched::scheduler::api::OBS_EVENT_NAMES;
use bayes_sched::workload::generator::{generate, WorkloadConfig};

fn small_cfg() -> RunConfig {
    RunConfig {
        scheduler: "bayes".into(),
        n_nodes: 4,
        n_racks: 2,
        workload: WorkloadConfig {
            n_jobs: 20,
            arrival_rate: 1.0,
            seed: 7,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("obs_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn read(dir: &Path, file: &str) -> String {
    std::fs::read_to_string(dir.join(file)).unwrap()
}

/// Run the small config with all three exporters on; return the makespan.
fn run_to_files(dir: &Path, sample: u64) -> f64 {
    run_with(dir, sample, None)
}

/// Same, optionally with the windowed snapshotter (and its CSV) on.
fn run_with(dir: &Path, sample: u64, window: Option<f64>) -> f64 {
    let opts = ObsOptions {
        dump: Some(dir.join("metrics.prom")),
        trace: Some(dir.join("trace.json")),
        jsonl: Some(dir.join("obs.jsonl")),
        csv: window.map(|_| dir.join("timeseries.csv")),
        window,
        sample,
        verbose: false,
    };
    let cfg = small_cfg();
    let cluster = Cluster::homogeneous(cfg.n_nodes, cfg.n_racks);
    let specs = generate(&cfg.workload);
    let mut jt = build_tracker_with(&cfg, cluster, specs).expect("build tracker");
    jt.enable_obs(&opts);
    jt.run();
    jt.finish_obs(&opts).expect("obs export");
    jt.metrics.makespan
}

#[test]
fn chrome_instants_match_sched_event_counters() {
    let dir = scratch("counts");
    run_to_files(&dir, 1);
    let prom = parse_prometheus(&read(&dir, "metrics.prom")).expect("parse prom");
    let chrome = chrome_event_counts(&read(&dir, "trace.json")).expect("parse trace");
    // instants are never sampled, so per event name the trace must agree
    // exactly with the counter the driver bumped on the same emit() path
    let mut total = 0.0;
    for name in OBS_EVENT_NAMES {
        let counted = prom.get(name).copied().unwrap_or(0.0);
        let instants = chrome.get(&format!("i:{name}")).copied().unwrap_or(0);
        assert_eq!(counted, instants as f64, "{name}");
        total += counted;
    }
    assert!(total > 0.0, "no SchedEvents observed at all");
    assert!(prom["engine_events_dispatched"] > 0.0);
    assert!(prom["driver_heartbeat_nanos_count"] > 0.0);
    assert!(prom["sched_bayes_assign_nanos_count"] > 0.0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn jsonl_round_trips_against_the_prom_snapshot() {
    let dir = scratch("jsonl");
    run_to_files(&dir, 1);
    let prom = parse_prometheus(&read(&dir, "metrics.prom")).expect("parse prom");
    let doc = parse_jsonl(&read(&dir, "obs.jsonl")).expect("parse jsonl");
    for name in OBS_EVENT_NAMES {
        let from_prom = prom.get(name).copied().unwrap_or(0.0);
        let from_jsonl = doc.counters.get(name).copied().unwrap_or(0);
        assert_eq!(from_prom, from_jsonl as f64, "{name}");
    }
    assert_eq!(
        doc.gauges["engine_events_dispatched"] as f64,
        prom["engine_events_dispatched"]
    );
    let (hb_count, _) = doc.histograms["driver_heartbeat_nanos"];
    assert_eq!(hb_count as f64, prom["driver_heartbeat_nanos_count"]);
    assert!(doc.instants > 0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sampling_is_deterministic_and_obs_never_perturbs_the_sim() {
    let d1 = scratch("s1");
    let d2 = scratch("s2");
    let d3 = scratch("s3");
    let m1 = run_to_files(&d1, 4);
    let m2 = run_to_files(&d2, 4);
    // identical seed + sample rate -> identical trace, bit for bit
    assert_eq!(m1.to_bits(), m2.to_bits());
    let c1 = chrome_event_counts(&read(&d1, "trace.json")).unwrap();
    let c2 = chrome_event_counts(&read(&d2, "trace.json")).unwrap();
    assert_eq!(c1, c2);

    // sampling thins duration spans but never instants
    let m3 = run_to_files(&d3, 1);
    assert_eq!(m1.to_bits(), m3.to_bits());
    let c3 = chrome_event_counts(&read(&d3, "trace.json")).unwrap();
    assert!(c1["X:heartbeat"] <= c3["X:heartbeat"]);
    for name in OBS_EVENT_NAMES {
        let key = format!("i:{name}");
        assert_eq!(c1.get(&key), c3.get(&key), "{name}");
    }

    // a run with obs fully off lands on the same makespan: instruments
    // only read the virtual clock, nothing feeds back
    let cfg = small_cfg();
    let cluster = Cluster::homogeneous(cfg.n_nodes, cfg.n_racks);
    let specs = generate(&cfg.workload);
    let mut jt = build_tracker_with(&cfg, cluster, specs).expect("build tracker");
    jt.run();
    assert_eq!(jt.metrics.makespan.to_bits(), m1.to_bits());
    for d in [d1, d2, d3] {
        std::fs::remove_dir_all(&d).ok();
    }
}

#[test]
fn windowed_snapshots_are_deterministic_and_sum_to_the_totals() {
    let d1 = scratch("w1");
    let d2 = scratch("w2");
    let d0 = scratch("w0");
    let m1 = run_with(&d1, 1, Some(60.0));
    let m2 = run_with(&d2, 1, Some(60.0));
    let m0 = run_to_files(&d0, 1);
    // the snapshotter only reads the registry at window boundaries, so
    // the sim is bit-identical with windows on, on again, and off
    assert_eq!(m1.to_bits(), m2.to_bits());
    assert_eq!(m1.to_bits(), m0.to_bits());

    let w1 = parse_jsonl(&read(&d1, "obs.jsonl")).unwrap().windows;
    let w2 = parse_jsonl(&read(&d2, "obs.jsonl")).unwrap().windows;
    assert!(!w1.is_empty(), "the run must close at least one window");
    assert_eq!(w1.len(), w2.len());
    for (a, b) in w1.iter().zip(&w2) {
        // sim-derived series match bit for bit across identical seeds;
        // wall-clock histograms need not, so compare the counter deltas
        assert_eq!(a.index, b.index);
        assert_eq!(a.sim_start.to_bits(), b.sim_start.to_bits());
        assert_eq!(a.sim_end.to_bits(), b.sim_end.to_bits());
        assert_eq!(a.counters, b.counters);
    }

    // every increment lands in exactly one window: the per-window deltas
    // sum back to the final snapshot totals
    let prom = parse_prometheus(&read(&d1, "metrics.prom")).unwrap();
    for name in OBS_EVENT_NAMES {
        let total = prom.get(name).copied().unwrap_or(0.0);
        assert_eq!(counter_total(&w1, name) as f64, total, "{name}");
    }

    let csv = read(&d1, "timeseries.csv");
    let mut lines = csv.lines();
    assert_eq!(
        lines.next(),
        Some("window,sim_start,sim_end,kind,name,value,sum,p50,p95,p99")
    );
    assert!(lines.count() > w1.len(), "windows emit one row per metric");
    for d in [d1, d2, d0] {
        std::fs::remove_dir_all(&d).ok();
    }
}

#[test]
fn a_kind_collision_detaches_the_handle_but_exports_stay_whole() {
    let registry = Registry::new();
    registry.counter("metric_x").add(3);
    let stray = registry.histogram("metric_x"); // wrong kind: collision
    stray.record(42);
    assert_eq!(stray.count(), 1, "detached handles still record");
    registry.histogram("queue_depth").record(5);

    // the real counter is untouched, the registry self-reports the
    // collision, and the stray histogram never reaches an export
    let snap = registry.snapshot();
    let prom = parse_prometheus(&to_prometheus(&snap)).unwrap();
    assert_eq!(prom["metric_x"], 3.0);
    assert_eq!(prom["obs_collisions"], 1.0);
    assert_eq!(prom["queue_depth_count"], 1.0);
    assert!(!prom.contains_key("metric_x_count"));

    // the JSONL exporter agrees sample for sample
    let doc = parse_jsonl(&to_jsonl(&snap, &Tracer::new(1), &[])).unwrap();
    assert_eq!(doc.counters["metric_x"], 3);
    assert_eq!(doc.counters["obs_collisions"], 1);
    assert_eq!(doc.histograms["queue_depth"].0, 1);
}

#[test]
fn e10_cells_write_suffixed_exporter_files_for_every_cell() {
    let dir = scratch("e10cells");
    let opts = ExpOpts {
        quick: true,
        out_dir: None,
        obs: ObsOptions {
            dump: Some(dir.join("metrics.prom")),
            ..ObsOptions::default()
        },
    };
    e10(&opts);
    // 2 mtbf points x 3 schedulers in quick mode, mtbf-major: cells 0..=5
    for i in 0..6 {
        let prom = parse_prometheus(&read(&dir, &format!("metrics.cell-{i}.prom")))
            .unwrap_or_else(|e| panic!("cell {i}: {e}"));
        assert_eq!(prom["obs_collisions"], 0.0, "cell {i}");
        assert!(prom["sched_ev_task_started"] > 0.0, "cell {i}");
    }
    // nothing writes the unsuffixed path, so no cell clobbers another
    assert!(!dir.join("metrics.prom").exists());
    std::fs::remove_dir_all(&dir).ok();
}
