//! Property-based invariants over the whole simulation stack (proptest
//! substitute: `bayes_sched::testkit::forall` with reproducible seeds).

use bayes_sched::bayes::classifier::{Classifier, Label, NaiveBayes};
use bayes_sched::bayes::features::{FeatureVec, N_FEATURES};
use bayes_sched::cluster::node::{Node, NodeId, NodeSpec};
use bayes_sched::cluster::resources::Resources;
use bayes_sched::cluster::Cluster;
use bayes_sched::coordinator::jobtracker::{JobTracker, TrackerConfig};
use bayes_sched::hdfs::Namespace;
use bayes_sched::job::task::{TaskKind, TaskRef};
use bayes_sched::job::JobId;
use bayes_sched::scheduler;
use bayes_sched::testkit::{forall, Gen};
use bayes_sched::workload::generator::{generate, Mix, WorkloadConfig};

fn random_workload(g: &mut Gen) -> WorkloadConfig {
    let mixes = [
        Mix::balanced(),
        Mix::cpu_fraction(g.float(0.0, 1.0)),
        Mix::only(*g.choose(&bayes_sched::job::profile::JobClass::ALL)),
    ];
    WorkloadConfig {
        n_jobs: g.int(3, 18) as usize,
        arrival_rate: g.float(0.2, 2.0),
        mix: mixes[g.index(3)].clone(),
        n_users: g.int(1, 6) as usize,
        seed: g.int(0, 1 << 30),
    }
}

/// Every scheduler finishes every workload; when it finishes, nodes are
/// empty and every task is Done with exactly `attempts >= 1`.
#[test]
fn prop_all_jobs_complete_under_every_scheduler() {
    forall("completion", 40, |g| {
        let sched_name = *g.choose(&scheduler::ALL_NAMES);
        let wl = random_workload(g);
        let n_nodes = g.int(2, 10) as u32;
        let cluster = Cluster::homogeneous(n_nodes, g.int(1, 3) as u32);
        let sched = scheduler::by_name(sched_name, wl.seed).unwrap();
        let specs = generate(&wl);
        let n_specs = specs.len();
        let mut jt =
            JobTracker::new(cluster, sched, specs, wl.seed, TrackerConfig::default());
        jt.run();
        assert!(jt.jobs.all_complete(), "{sched_name} stalled");
        // every job terminates: success (outcome) or max-attempts kill
        assert_eq!(
            jt.metrics.completed_jobs() + jt.jobs.failed_count(),
            n_specs,
            "{sched_name}"
        );
        for node in &jt.cluster.nodes {
            assert!(node.running().is_empty());
        }
        for job in jt.jobs.iter().filter(|j| !j.failed) {
            for t in job.maps.iter().chain(&job.reduces) {
                assert!(t.is_done());
                assert!(t.attempts >= 1);
            }
            // outcome sanity
            let o = job.outcome().unwrap();
            assert!(o.finish_time >= o.submit_time);
            if let Some(fl) = o.first_launch {
                assert!(fl >= o.submit_time && fl <= o.finish_time);
            }
        }
    });
}

/// Same seed ⇒ byte-identical metrics; different seed ⇒ different trace.
#[test]
fn prop_simulation_is_deterministic() {
    forall("determinism", 15, |g| {
        let wl = random_workload(g);
        let run = |seed: u64| {
            let cluster = Cluster::homogeneous(4, 2);
            let sched = scheduler::by_name("bayes", seed).unwrap();
            let mut w = wl.clone();
            w.seed = seed;
            let mut jt =
                JobTracker::new(cluster, sched, generate(&w), seed, TrackerConfig::default());
            jt.run();
            (
                jt.metrics.makespan,
                jt.engine.processed(),
                jt.metrics.latencies(),
                jt.metrics.feedback,
            )
        };
        let s = g.int(0, 1 << 20);
        assert_eq!(run(s), run(s));
    });
}

/// Slots are never oversubscribed during a run, and every batch honors the
/// batch contract. Checked via a scheduler wrapper that inspects the node
/// and the returned batch at every heartbeat.
#[test]
fn prop_slots_never_oversubscribed() {
    struct Watch(Box<dyn scheduler::Scheduler>);
    impl scheduler::Scheduler for Watch {
        fn name(&self) -> &'static str {
            "watch"
        }
        fn assign(
            &mut self,
            view: &scheduler::SchedView,
            node: &Node,
            budget: scheduler::SlotBudget,
        ) -> Vec<scheduler::Assignment> {
            assert!(node.used_slots(TaskKind::Map) <= node.spec.map_slots);
            assert!(node.used_slots(TaskKind::Reduce) <= node.spec.reduce_slots);
            let out = self.0.assign(view, node, budget);
            // batch contract: per-kind budget respected, no task twice
            let maps =
                out.iter().filter(|a| a.task.kind == TaskKind::Map).count() as u32;
            let reduces = out.len() as u32 - maps;
            assert!(maps <= budget.maps, "map budget exceeded");
            assert!(reduces <= budget.reduces, "reduce budget exceeded");
            for (i, a) in out.iter().enumerate() {
                assert!(
                    !out[..i].iter().any(|b| b.task == a.task),
                    "task {} assigned twice in one batch",
                    a.task
                );
            }
            out
        }
        fn observe(&mut self, ev: &scheduler::SchedEvent) {
            self.0.observe(ev);
        }
    }
    forall("slots", 20, |g| {
        let wl = random_workload(g);
        let inner = scheduler::by_name(*g.choose(&scheduler::ALL_NAMES), wl.seed).unwrap();
        let cluster = Cluster::homogeneous(g.int(2, 6) as u32, 2);
        let mut jt = JobTracker::new(
            cluster,
            Box::new(Watch(inner)),
            generate(&wl),
            wl.seed,
            TrackerConfig::default(),
        );
        jt.run();
        for node in &jt.cluster.nodes {
            assert!(node.used_slots(TaskKind::Map) == 0);
        }
    });
}

/// Classifier counts always equal the feedback fed in; posteriors stay in
/// [0, 1]; flush is idempotent.
#[test]
fn prop_classifier_count_conservation() {
    forall("classifier-counts", 100, |g| {
        let mut nb = NaiveBayes::new(g.float(0.05, 5.0) as f32);
        let n = g.int(1, 400);
        let mut good = 0f32;
        let mut bad = 0f32;
        for _ in 0..n {
            let mut fv: FeatureVec = [0; N_FEATURES];
            for b in fv.iter_mut() {
                *b = g.int(0, 9) as u8;
            }
            let label = if g.rng.chance(0.5) {
                good += 1.0;
                Label::Good
            } else {
                bad += 1.0;
                Label::Bad
            };
            nb.observe(fv, label);
            let p = nb.posterior_good(&fv);
            assert!((0.0..=1.0).contains(&p) && p.is_finite());
        }
        nb.flush();
        nb.flush(); // idempotent
        assert_eq!(nb.class_counts(), [good, bad]);
        let (counts, _) = nb.state();
        let total: f32 = counts.iter().sum();
        assert_eq!(total, (good + bad) * N_FEATURES as f32);
    });
}

/// Node work accounting conserves work: total work drained equals the sum
/// of (elapsed × effective speed) across intervals, regardless of the
/// add/remove pattern.
#[test]
fn prop_node_work_conservation() {
    forall("node-work", 100, |g| {
        let mut node = Node::new(NodeId(0), NodeSpec::default());
        let mut now = 0.0;
        let mut active: Vec<TaskRef> = Vec::new();
        let mut next_idx = 0u32;
        for _ in 0..g.int(1, 30) {
            now += g.float(0.1, 5.0);
            node.advance(now);
            let add = active.is_empty()
                || (g.rng.chance(0.6) && node.free_slots(TaskKind::Map) > 0);
            if add {
                let tref =
                    TaskRef { job: JobId::dense(0), kind: TaskKind::Map, index: next_idx };
                next_idx += 1;
                let demand = Resources::new(
                    g.float(0.05, 0.9),
                    g.float(0.05, 0.6),
                    g.float(0.0, 0.5),
                    g.float(0.0, 0.5),
                );
                node.add_task(tref, demand, g.float(1.0, 50.0), now);
                active.push(tref);
            } else {
                let idx = g.index(active.len());
                let tref = active.swap_remove(idx);
                let (rec, _) = node.remove_task(&tref, now);
                assert!(rec.remaining >= 0.0);
            }
            // effective speed bounded by base speed
            assert!(node.effective_speed() <= node.spec.speed + 1e-12);
            assert!(node.slowdown() >= 1.0);
        }
    });
}

/// HDFS: every block's replicas are distinct nodes, and locality
/// classification is consistent with the replica list.
#[test]
fn prop_hdfs_replicas_distinct_and_locality_consistent() {
    forall("hdfs", 60, |g| {
        let n_nodes = g.int(1, 30) as u32;
        let n_racks = g.int(1, 6) as u32;
        let mut ns = Namespace::new(n_nodes, n_racks, g.int(0, 1 << 30));
        for b in ns.allocate_blocks(g.int(1, 50) as usize) {
            let reps = ns.replicas(b).to_vec();
            assert!(!reps.is_empty());
            assert!(reps.len() <= 3.min(n_nodes as usize));
            let mut d = reps.clone();
            d.sort();
            d.dedup();
            assert_eq!(d.len(), reps.len(), "duplicate replicas");
            for node in reps.iter() {
                assert_eq!(
                    ns.locality(b, *node),
                    bayes_sched::hdfs::Locality::NodeLocal
                );
            }
        }
    });
}

/// FIFO ordering: with equal priorities and a single-slot cluster, FIFO
/// launches jobs' first tasks in submission order.
#[test]
fn prop_fifo_respects_submission_order() {
    forall("fifo-order", 20, |g| {
        let mut wl = random_workload(g);
        wl.n_jobs = g.int(3, 8) as usize;
        let mut specs = generate(&wl);
        for s in &mut specs {
            s.priority = bayes_sched::bayes::utility::Priority::Normal;
        }
        let cluster = Cluster::with_specs(
            vec![NodeSpec { map_slots: 1, reduce_slots: 1, ..Default::default() }],
            1,
        );
        let mut jt = JobTracker::new(
            cluster,
            scheduler::by_name("fifo", 0).unwrap(),
            specs,
            wl.seed,
            TrackerConfig::default(),
        );
        jt.run();
        let mut launches: Vec<(f64, u32)> = jt
            .jobs
            .iter()
            .map(|j| (j.first_launch.unwrap(), j.id.0))
            .collect();
        launches.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let order: Vec<u32> = launches.iter().map(|(_, id)| *id).collect();
        let sorted: Vec<u32> = (0..order.len() as u32).collect();
        assert_eq!(order, sorted, "FIFO launched out of submission order");
    });
}
