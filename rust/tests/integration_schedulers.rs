//! Scheduler-behaviour integration: the policy-level properties that
//! distinguish FIFO / Fair / Capacity / Bayes (paper §3-§4) on controlled
//! workloads.

use bayes_sched::bayes::classifier::NaiveBayes;
use bayes_sched::bayes::utility::Priority;
use bayes_sched::cluster::Cluster;
use bayes_sched::coordinator::jobtracker::{JobTracker, TrackerConfig};
use bayes_sched::job::profile::JobClass;
use bayes_sched::metrics::stats;
use bayes_sched::scheduler::{self, BayesScheduler, Scheduler};
use bayes_sched::workload::generator::{generate, Mix, WorkloadConfig};

fn run_with(
    sched: Box<dyn Scheduler>,
    wl: &WorkloadConfig,
    nodes: u32,
) -> JobTracker {
    let mut jt = JobTracker::new(
        Cluster::homogeneous(nodes, 2),
        sched,
        generate(wl),
        wl.seed,
        TrackerConfig::default(),
    );
    jt.run();
    jt
}

#[test]
fn fifo_priority_beats_submission_order() {
    // one VeryHigh job submitted late must start before Normal jobs that
    // arrived earlier but have not launched yet
    let wl = WorkloadConfig { n_jobs: 12, arrival_rate: 5.0, seed: 21, ..Default::default() };
    let mut specs = generate(&wl);
    for s in specs.iter_mut() {
        s.priority = Priority::Normal;
    }
    specs[11].priority = Priority::VeryHigh;
    let mut jt = JobTracker::new(
        Cluster::homogeneous(2, 1),
        scheduler::by_name("fifo", 21).unwrap(),
        specs,
        21,
        TrackerConfig::default(),
    );
    jt.run();
    let high_launch = jt.jobs.get(bayes_sched::job::JobId::dense(11)).first_launch.unwrap();
    // at least one earlier-submitted Normal job should launch after it
    let later = jt
        .jobs
        .iter()
        .filter(|j| j.id.0 != 11)
        .filter(|j| j.first_launch.unwrap() > high_launch)
        .count();
    assert!(later > 0, "priority job gained nothing");
}

#[test]
fn fair_spreads_across_users_better_than_fifo() {
    // 2 users: user0 submits a burst of big jobs first, user1's small jobs
    // arrive just after; fair should serve user1 sooner on average
    let wl = WorkloadConfig {
        n_jobs: 16,
        arrival_rate: 4.0,
        n_users: 2,
        seed: 22,
        ..Default::default()
    };
    let wait_by_user = |jt: &JobTracker, user: &str| {
        let ws: Vec<f64> = jt
            .jobs
            .iter()
            .filter(|j| j.spec.user == user)
            .map(|j| j.first_launch.unwrap() - j.spec.submit_time)
            .collect();
        stats::mean(&ws)
    };
    let fifo = run_with(scheduler::by_name("fifo", 22).unwrap(), &wl, 3);
    let fair = run_with(scheduler::by_name("fair", 22).unwrap(), &wl, 3);
    // fairness index over mean waits should not degrade under fair
    let f_fifo = stats::jain_fairness(&[
        wait_by_user(&fifo, "user0") + 1.0,
        wait_by_user(&fifo, "user1") + 1.0,
    ]);
    let f_fair = stats::jain_fairness(&[
        wait_by_user(&fair, "user0") + 1.0,
        wait_by_user(&fair, "user1") + 1.0,
    ]);
    assert!(
        f_fair >= f_fifo - 0.05,
        "fair scheduler less fair than fifo: {f_fair} vs {f_fifo}"
    );
}

#[test]
fn capacity_respects_queue_shares() {
    // all jobs in one queue vs spread over three: the scheduler must not
    // stall either way (regression guard for the total_slots wiring)
    for seed in [23u64, 24] {
        let wl = WorkloadConfig { n_jobs: 20, arrival_rate: 2.0, seed, ..Default::default() };
        let jt = run_with(scheduler::by_name("capacity", seed).unwrap(), &wl, 4);
        assert!(jt.jobs.all_complete());
        // capacity should not be catastrophically slower than fifo
        let fifo = run_with(scheduler::by_name("fifo", seed).unwrap(), &wl, 4);
        assert!(
            jt.metrics.makespan < fifo.metrics.makespan * 2.0,
            "capacity pathologically slow: {} vs {}",
            jt.metrics.makespan,
            fifo.metrics.makespan
        );
    }
}

#[test]
fn bayes_reduces_overload_rate_vs_fifo() {
    let wl = WorkloadConfig {
        n_jobs: 120,
        arrival_rate: 1.0,
        mix: Mix::cpu_fraction(0.6),
        seed: 25,
        ..Default::default()
    };
    let fifo = run_with(scheduler::by_name("fifo", 25).unwrap(), &wl, 10);
    let bayes = run_with(scheduler::by_name("bayes", 25).unwrap(), &wl, 10);
    assert!(bayes.jobs.all_complete());
    assert!(
        bayes.metrics.overload_rate() < fifo.metrics.overload_rate() * 0.8,
        "bayes {} vs fifo {}",
        bayes.metrics.overload_rate(),
        fifo.metrics.overload_rate()
    );
}

#[test]
fn bayes_warm_start_beats_cold_start() {
    // The clean test of "learning helps": run the same workload with a
    // fresh classifier vs one warmed on a previous identical run. The warm
    // classifier must overload less from the start. (The within-run window
    // curve confounds learning with queue-load ramp; E3 reports it against
    // a fifo control instead.)
    let wl = WorkloadConfig {
        n_jobs: 150,
        arrival_rate: 1.0,
        mix: Mix::cpu_fraction(0.5),
        seed: 26,
        ..Default::default()
    };
    use bayes_sched::bayes::classifier::{Classifier, Label};
    use bayes_sched::bayes::features::FeatureVec;
    use bayes_sched::scheduler::SchedEvent;
    let cold = run_with(
        Box::new(BayesScheduler::new(NaiveBayes::new(1.0))),
        &wl,
        10,
    );
    // Tap the cold run's feedback stream (rerun is deterministic) and
    // train a warm classifier from it offline.
    struct Tap {
        inner: BayesScheduler<NaiveBayes>,
        samples: std::rc::Rc<std::cell::RefCell<Vec<(FeatureVec, Label)>>>,
    }
    impl Scheduler for Tap {
        fn name(&self) -> &'static str {
            "tap"
        }
        fn assign(
            &mut self,
            v: &bayes_sched::scheduler::SchedView,
            n: &bayes_sched::cluster::node::Node,
            b: bayes_sched::scheduler::SlotBudget,
        ) -> Vec<bayes_sched::scheduler::Assignment> {
            self.inner.assign(v, n, b)
        }
        fn observe(&mut self, ev: &SchedEvent) {
            if let SchedEvent::Feedback { feats, label } = ev {
                self.samples.borrow_mut().push((*feats, *label));
            }
            self.inner.observe(ev);
        }
    }
    let samples = std::rc::Rc::new(std::cell::RefCell::new(Vec::new()));
    let tap = Tap {
        inner: BayesScheduler::new(NaiveBayes::new(1.0)),
        samples: samples.clone(),
    };
    run_with(Box::new(tap), &wl, 10);
    let mut warm_nb = NaiveBayes::new(1.0);
    for (f, l) in samples.borrow().iter() {
        warm_nb.observe(*f, *l);
    }
    warm_nb.flush();
    let warm = run_with(Box::new(BayesScheduler::new(warm_nb)), &wl, 10);
    assert!(warm.jobs.all_complete());
    assert!(
        warm.metrics.overload_rate() <= cold.metrics.overload_rate() + 0.02,
        "warm {} vs cold {}",
        warm.metrics.overload_rate(),
        cold.metrics.overload_rate()
    );
}

#[test]
fn bayes_no_utility_changes_selection() {
    use bayes_sched::bayes::utility::UtilityFn;
    let wl = WorkloadConfig { n_jobs: 40, arrival_rate: 1.5, seed: 27, ..Default::default() };
    let full = run_with(
        Box::new(BayesScheduler::new(NaiveBayes::new(1.0))),
        &wl,
        4,
    );
    let no_util = run_with(
        Box::new(
            BayesScheduler::new(NaiveBayes::new(1.0))
                .with_utility(UtilityFn::constant()),
        ),
        &wl,
        4,
    );
    assert!(full.jobs.all_complete() && no_util.jobs.all_complete());
    // the runs must actually differ (utility is load-bearing)
    assert_ne!(
        full.metrics.latencies(),
        no_util.metrics.latencies(),
        "utility function had no effect"
    );
}

#[test]
fn threshold_fifo_also_avoids_overload_but_needs_the_right_threshold() {
    // the hand-tuned avoider with a good threshold reduces overloads vs
    // fifo — sanity for the E8/E9 comparison axis
    let wl = WorkloadConfig {
        n_jobs: 80,
        arrival_rate: 1.0,
        mix: Mix::cpu_fraction(0.7),
        seed: 28,
        ..Default::default()
    };
    let fifo = run_with(scheduler::by_name("fifo", 28).unwrap(), &wl, 8);
    let thresh = run_with(
        Box::new(scheduler::ThresholdFifo::new(0.9)),
        &wl,
        8,
    );
    assert!(thresh.jobs.all_complete());
    assert!(thresh.metrics.overload_rate() < fifo.metrics.overload_rate());
}

#[test]
fn random_scheduler_is_a_valid_lower_bound() {
    let wl = WorkloadConfig { n_jobs: 30, seed: 29, ..Default::default() };
    let rand_run = run_with(scheduler::by_name("random", 29).unwrap(), &wl, 4);
    assert!(rand_run.jobs.all_complete());
}

// ------------------------------------------------------ state-leak guards --

/// Scheduler wrapper sharing its inner state with the test, so per-job
/// bookkeeping can be inspected *after* a full simulation (the tracker
/// owns the scheduler as `Box<dyn Scheduler>`).
struct Shared<S: Scheduler>(std::rc::Rc<std::cell::RefCell<S>>);

impl<S: Scheduler> Scheduler for Shared<S> {
    fn name(&self) -> &'static str {
        "shared"
    }
    fn assign(
        &mut self,
        v: &bayes_sched::scheduler::SchedView,
        n: &bayes_sched::cluster::node::Node,
        b: bayes_sched::scheduler::SlotBudget,
    ) -> Vec<bayes_sched::scheduler::Assignment> {
        self.0.borrow_mut().assign(v, n, b)
    }
    fn observe(&mut self, ev: &bayes_sched::scheduler::SchedEvent) {
        self.0.borrow_mut().observe(ev);
    }
}

#[test]
fn fair_job_pool_is_empty_after_a_full_run() {
    // regression: job_pool entries used to be inserted on every heartbeat
    // and never removed — one BTreeMap entry leaked per job forever
    let wl = WorkloadConfig {
        n_jobs: 30,
        arrival_rate: 2.0,
        n_users: 3,
        seed: 91,
        ..Default::default()
    };
    let fair = std::rc::Rc::new(std::cell::RefCell::new(
        bayes_sched::scheduler::Fair::new(),
    ));
    let jt = run_with(Box::new(Shared(fair.clone())), &wl, 4);
    assert!(jt.jobs.all_complete());
    assert_eq!(
        fair.borrow().tracked_jobs(),
        0,
        "Fair::job_pool leaked entries after all jobs completed"
    );
}

#[test]
fn fair_job_pool_is_empty_even_under_failure_churn() {
    use bayes_sched::coordinator::jobtracker::{FailureConfig, TrackerConfig};
    let wl = WorkloadConfig {
        n_jobs: 20,
        arrival_rate: 1.0,
        n_users: 3,
        seed: 92,
        ..Default::default()
    };
    let fair = std::rc::Rc::new(std::cell::RefCell::new(
        bayes_sched::scheduler::Fair::new(),
    ));
    let mut cfg = TrackerConfig::default();
    cfg.failures = FailureConfig { mtbf: Some(250.0), mttr: 40.0 };
    let mut jt = bayes_sched::coordinator::jobtracker::JobTracker::new(
        Cluster::homogeneous(5, 2),
        Box::new(Shared(fair.clone())),
        generate(&wl),
        92,
        cfg,
    );
    jt.run();
    assert!(jt.jobs.all_complete());
    // killed jobs drain too: JobCompleted fires after the last attempt
    assert_eq!(fair.borrow().tracked_jobs(), 0, "job_pool leaked under churn");
}

#[test]
fn capacity_job_queue_is_empty_after_a_full_run() {
    // the same leak pattern audited in Capacity
    let wl = WorkloadConfig {
        n_jobs: 25,
        arrival_rate: 2.0,
        n_users: 3,
        seed: 93,
        ..Default::default()
    };
    let cap = std::rc::Rc::new(std::cell::RefCell::new(
        bayes_sched::scheduler::Capacity::new(),
    ));
    let jt = run_with(Box::new(Shared(cap.clone())), &wl, 4);
    assert!(jt.jobs.all_complete());
    assert_eq!(
        cap.borrow().tracked_jobs(),
        0,
        "Capacity::job_queue leaked entries after all jobs completed"
    );
}

// ------------------------------------------------------------ speculation --

#[test]
fn speculation_fires_on_a_heterogeneous_cluster_and_nothing_breaks() {
    // one crawling node makes its tasks run far past their peers' median:
    // the straggler path should launch backups, and whether each backup
    // wins or loses, the run must stay consistent
    use bayes_sched::cluster::node::NodeSpec;
    use bayes_sched::cluster::resources::Resources;
    use bayes_sched::coordinator::jobtracker::{JobTracker, TrackerConfig};
    let fast = NodeSpec::default();
    let crawler = NodeSpec {
        capacity: Resources::splat(0.6),
        speed: 0.25,
        map_slots: 2,
        reduce_slots: 2,
    };
    let classes = [(fast, 0.75), (crawler, 0.25)];
    let cluster = Cluster::heterogeneous(8, 2, &classes, 5);
    let wl = WorkloadConfig {
        n_jobs: 40,
        arrival_rate: 0.8,
        seed: 94,
        ..Default::default()
    };
    let mut jt = JobTracker::new(
        cluster,
        scheduler::by_name("bayes", 94).unwrap(),
        generate(&wl),
        94,
        TrackerConfig::default(),
    );
    jt.run();
    assert!(jt.jobs.all_complete());
    for n in &jt.cluster.nodes {
        assert!(n.running().is_empty(), "{} busy after drain", n.id);
    }
    assert!(
        jt.metrics.speculative_launches > 0,
        "no backups launched despite a 4x-slow node class"
    );
    assert!(
        jt.metrics.speculative_wins <= jt.metrics.speculative_launches,
        "more wins than launches"
    );
}
