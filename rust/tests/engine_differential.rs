//! Differential determinism: the calendar-queue engine (`Engine`) and the
//! binary-heap reference (`HeapEngine`) must be observationally identical —
//! same pop order (FIFO on time ties), same clamping of past and non-finite
//! times, same counters — under adversarial random schedules. The queue
//! backend is an implementation detail; the engine contract is the API.

use bayes_sched::cluster::node::NodeId;
use bayes_sched::sim::engine::{EngineImpl, HeapQueue};
use bayes_sched::sim::{CalendarQueue, Event, EventQueue, Pcg};

/// One pre-generated operation, applied identically to both engines.
#[derive(Debug, Clone)]
enum Op {
    Schedule(f64, u32),
    Pop,
}

/// Build an adversarial op sequence: heavy time ties (coarse grid), past
/// times (clamped to now), NaN and both infinities (clamped), interleaved
/// with pops so the clock advances mid-sequence.
fn adversarial_ops(seed: u64, n: usize) -> Vec<Op> {
    let mut rng = Pcg::seeded(seed);
    let mut ops = Vec::with_capacity(n);
    for i in 0..n {
        if rng.below(3) < 2 {
            let at = match rng.below(12) {
                0 => f64::NAN,
                1 => f64::INFINITY,
                2 => f64::NEG_INFINITY,
                3 => -1.5,
                // coarse grid => frequent exact ties
                _ => rng.below(200) as f64 * 0.5,
            };
            ops.push(Op::Schedule(at, i as u32));
        } else {
            ops.push(Op::Pop);
        }
    }
    ops
}

/// Run the ops on one backend, recording every pop as `(time bits, event)`
/// plus the final counters. Drains the queue at the end so the full order
/// is compared, not just the interleaved prefix.
fn run<Q: EventQueue + Default>(ops: &[Op]) -> (Vec<(u64, Event)>, u64, u64) {
    let mut e: EngineImpl<Q> = EngineImpl::new();
    let mut pops = Vec::new();
    for op in ops {
        match op {
            Op::Schedule(at, id) => e.schedule(*at, Event::Heartbeat(NodeId(*id))),
            Op::Pop => {
                if let Some((t, ev)) = e.pop() {
                    pops.push((t.to_bits(), ev));
                }
            }
        }
    }
    while let Some((t, ev)) = e.pop() {
        pops.push((t.to_bits(), ev));
    }
    (pops, e.clamped_events(), e.processed())
}

#[test]
fn calendar_and_heap_agree_on_adversarial_schedules() {
    for seed in [1u64, 7, 42, 1234, 99999] {
        let ops = adversarial_ops(seed, 4000);
        let (heap_pops, heap_clamped, heap_proc) = run::<HeapQueue>(&ops);
        let (cal_pops, cal_clamped, cal_proc) = run::<CalendarQueue>(&ops);
        assert_eq!(heap_pops.len(), cal_pops.len(), "seed {seed}: pop counts");
        for (i, (h, c)) in heap_pops.iter().zip(cal_pops.iter()).enumerate() {
            assert_eq!(h, c, "seed {seed}: divergence at pop {i}");
        }
        assert_eq!(heap_clamped, cal_clamped, "seed {seed}: clamped_events");
        assert_eq!(heap_proc, cal_proc, "seed {seed}: processed");
        // the adversarial palette must actually exercise the clamp path
        assert!(heap_clamped > 0, "seed {seed}: no clamped events generated");
    }
}

#[test]
fn pure_tie_storm_pops_in_submission_order() {
    // every event at the same instant: both backends must emit pure FIFO
    let mut heap: EngineImpl<HeapQueue> = EngineImpl::new();
    let mut cal: EngineImpl<CalendarQueue> = EngineImpl::new();
    for i in 0..500u32 {
        heap.schedule(5.0, Event::Heartbeat(NodeId(i)));
        cal.schedule(5.0, Event::Heartbeat(NodeId(i)));
    }
    for i in 0..500u32 {
        let want = Some((5.0, Event::Heartbeat(NodeId(i))));
        assert_eq!(heap.pop(), want, "heap FIFO at {i}");
        assert_eq!(cal.pop(), want, "calendar FIFO at {i}");
    }
    assert!(heap.pop().is_none() && cal.pop().is_none());
}
