//! Trait-conformance suite for the batched scheduler API: every `by_name`
//! scheduler must uphold the batch invariants under arbitrary budgets and
//! tolerate `observe` events in any driver interleaving — the contract both
//! the MRv1 JobTracker and the YARN ResourceManager drivers rely on.

use bayes_sched::bayes::classifier::Label;
use bayes_sched::bayes::features::N_FEATURES;
use bayes_sched::bayes::utility::Priority;
use bayes_sched::cluster::node::{Node, NodeId, NodeSpec};
use bayes_sched::hdfs::Namespace;
use bayes_sched::job::job::JobSpec;
use bayes_sched::job::profile::JobClass;
use bayes_sched::job::queue::JobTable;
use bayes_sched::job::task::{TaskKind, TaskRef};
use bayes_sched::job::JobId;
use bayes_sched::scheduler::{self, Assignment, SchedEvent, SchedView, SlotBudget};

fn spec(name: &str, user: &str, class: JobClass, maps: usize, reduces: usize) -> JobSpec {
    JobSpec {
        name: name.into(),
        user: user.into(),
        pool: user.into(),
        queue: format!("q_{user}"),
        class,
        priority: Priority::Normal,
        profile: class.base_features(),
        map_works: vec![10.0; maps],
        reduce_works: vec![15.0; reduces],
        submit_time: 0.0,
    }
}

struct Fixture {
    jobs: JobTable,
    hdfs: Namespace,
}

/// Four jobs over two users; job 3's map phase is already complete, so its
/// reduces are the only legally assignable reduces in the fixture.
fn fixture() -> Fixture {
    let mut hdfs = Namespace::new(4, 2, 17);
    let mut jobs = JobTable::new();
    jobs.submit(spec("a", "u0", JobClass::Small, 3, 1), &mut hdfs);
    jobs.submit(spec("b", "u1", JobClass::CpuHeavy, 4, 2), &mut hdfs);
    jobs.submit(spec("c", "u0", JobClass::IoHeavy, 2, 1), &mut hdfs);
    jobs.submit(spec("d", "u1", JobClass::Small, 2, 2), &mut hdfs);
    // drive job 3 (id 3) through its map phase
    for index in 0..2 {
        let t = TaskRef { job: JobId(3), kind: TaskKind::Map, index };
        jobs.start_task(&t, NodeId(0), 1.0);
        jobs.complete_task(&t, 5.0);
    }
    assert!(jobs.get(JobId(3)).maps_complete());
    Fixture { jobs, hdfs }
}

fn big_node() -> Node {
    Node::new(
        NodeId(1),
        NodeSpec { map_slots: 8, reduce_slots: 8, ..Default::default() },
    )
}

fn assign(
    f: &Fixture,
    sched: &mut dyn scheduler::Scheduler,
    node: &Node,
    budget: SlotBudget,
) -> Vec<Assignment> {
    let queue = f.jobs.schedulable();
    let view = SchedView { jobs: &f.jobs, hdfs: &f.hdfs, queue: &queue, now: 50.0 };
    sched.assign(&view, node, budget)
}

/// The batch contract (see scheduler/api.rs module docs).
fn check_batch(name: &str, f: &Fixture, out: &[Assignment], budget: SlotBudget) {
    let maps = out.iter().filter(|a| a.task.kind == TaskKind::Map).count() as u32;
    let reduces = out.len() as u32 - maps;
    assert!(maps <= budget.maps, "{name}: map budget exceeded ({maps} > {})", budget.maps);
    assert!(
        reduces <= budget.reduces,
        "{name}: reduce budget exceeded ({reduces} > {})",
        budget.reduces
    );
    for (i, a) in out.iter().enumerate() {
        assert!(
            !out[..i].iter().any(|b| b.task == a.task),
            "{name}: task {} assigned twice in one batch",
            a.task
        );
        let job = f.jobs.get(a.task.job);
        assert!(
            job.task(&a.task).is_pending(),
            "{name}: assigned non-pending task {}",
            a.task
        );
        if a.task.kind == TaskKind::Reduce {
            assert!(
                job.maps_complete(),
                "{name}: reduce {} assigned before maps_complete()",
                a.task
            );
        }
        // the decision record must describe the assignment
        assert_eq!(a.decision.job, a.task.job, "{name}: decision/job mismatch");
        assert_eq!(a.decision.kind, a.task.kind, "{name}: decision/kind mismatch");
        assert!(a.decision.candidates > 0, "{name}: zero candidates recorded");
    }
}

#[test]
fn batch_invariants_hold_for_every_scheduler_and_budget() {
    let budgets = [
        SlotBudget { maps: 0, reduces: 0 },
        SlotBudget { maps: 1, reduces: 0 },
        SlotBudget { maps: 0, reduces: 1 },
        SlotBudget { maps: 4, reduces: 2 },
        SlotBudget { maps: 16, reduces: 16 },
    ];
    for name in scheduler::ALL_NAMES {
        for budget in budgets {
            let f = fixture();
            let mut s = scheduler::by_name(name, 7).unwrap();
            s.observe(&SchedEvent::ClusterInfo { total_slots: 32 });
            let out = assign(&f, s.as_mut(), &big_node(), budget);
            check_batch(name, &f, &out, budget);
            if budget.total() == 0 {
                assert!(out.is_empty(), "{name}: assigned with zero budget");
            }
        }
    }
}

#[test]
fn batch_exhausts_work_not_budget() {
    // one small job with 2 maps: a huge budget must yield exactly those 2
    // maps (reduces stay gated), for every scheduler
    for name in scheduler::ALL_NAMES {
        let mut hdfs = Namespace::new(4, 2, 3);
        let mut jobs = JobTable::new();
        jobs.submit(spec("only", "u0", JobClass::Small, 2, 3), &mut hdfs);
        let f = Fixture { jobs, hdfs };
        let mut s = scheduler::by_name(name, 5).unwrap();
        s.observe(&SchedEvent::ClusterInfo { total_slots: 32 });
        let out = assign(&f, s.as_mut(), &big_node(), SlotBudget { maps: 8, reduces: 8 });
        assert_eq!(out.len(), 2, "{name}: expected both maps, got {}", out.len());
        assert!(out.iter().all(|a| a.task.kind == TaskKind::Map), "{name}");
        check_batch(name, &f, &out, SlotBudget { maps: 8, reduces: 8 });
    }
}

#[test]
fn reduces_never_assigned_before_map_phase() {
    // nothing in this fixture has a complete map phase
    for name in scheduler::ALL_NAMES {
        let mut hdfs = Namespace::new(4, 2, 11);
        let mut jobs = JobTable::new();
        jobs.submit(spec("x", "u0", JobClass::Small, 2, 2), &mut hdfs);
        jobs.submit(spec("y", "u1", JobClass::NetHeavy, 3, 4), &mut hdfs);
        let f = Fixture { jobs, hdfs };
        let mut s = scheduler::by_name(name, 2).unwrap();
        let out = assign(&f, s.as_mut(), &big_node(), SlotBudget { maps: 0, reduces: 8 });
        assert!(
            out.is_empty(),
            "{name}: assigned a reduce before any map phase finished"
        );
    }
}

#[test]
fn observe_tolerates_any_event_interleaving() {
    let events = [
        SchedEvent::TaskFinished { job: JobId(9) }, // never started
        SchedEvent::Feedback { feats: [9; N_FEATURES], label: Label::Bad },
        SchedEvent::JobCompleted { job: JobId(5) }, // never seen
        SchedEvent::TaskStarted { job: JobId(0) },
        SchedEvent::ClusterInfo { total_slots: 64 },
        SchedEvent::TaskFinished { job: JobId(0) },
        SchedEvent::TaskFinished { job: JobId(0) }, // more finishes than starts
        SchedEvent::Feedback { feats: [0; N_FEATURES], label: Label::Good },
    ];
    for name in scheduler::ALL_NAMES {
        let mut s = scheduler::by_name(name, 3).unwrap();
        // forward, reversed, and doubled orders must all be absorbed
        for ev in &events {
            s.observe(ev);
        }
        for ev in events.iter().rev() {
            s.observe(ev);
        }
        // assignment still works and still honors the contract afterwards
        let f = fixture();
        let budget = SlotBudget { maps: 4, reduces: 4 };
        let out = assign(&f, s.as_mut(), &big_node(), budget);
        check_batch(name, &f, &out, budget);
    }
}

#[test]
fn observe_between_batches_keeps_batches_valid() {
    // interleave realistic started/finished events with repeated batches;
    // each batch must independently satisfy the contract
    for name in scheduler::ALL_NAMES {
        let f = fixture();
        let mut s = scheduler::by_name(name, 13).unwrap();
        s.observe(&SchedEvent::ClusterInfo { total_slots: 16 });
        let budget = SlotBudget { maps: 2, reduces: 1 };
        for round in 0..4 {
            let out = assign(&f, s.as_mut(), &big_node(), budget);
            check_batch(name, &f, &out, budget);
            for a in &out {
                s.observe(&SchedEvent::TaskStarted { job: a.task.job });
            }
            if round % 2 == 1 {
                for a in &out {
                    s.observe(&SchedEvent::TaskFinished { job: a.task.job });
                }
            }
        }
    }
}
