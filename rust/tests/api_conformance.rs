//! Trait-conformance suite for the batched scheduler API: every `by_name`
//! scheduler must uphold the batch invariants under arbitrary budgets and
//! tolerate `observe` events in any driver interleaving — the contract both
//! the MRv1 JobTracker and the YARN ResourceManager drivers rely on.

use bayes_sched::bayes::classifier::Label;
use bayes_sched::bayes::features::{FailureHistory, N_FEATURES};
use bayes_sched::bayes::utility::Priority;
use bayes_sched::cluster::node::{Node, NodeId, NodeSpec};
use bayes_sched::hdfs::Namespace;
use bayes_sched::job::job::JobSpec;
use bayes_sched::job::profile::JobClass;
use bayes_sched::job::queue::JobTable;
use bayes_sched::job::task::{TaskKind, TaskRef};
use bayes_sched::job::JobId;
use bayes_sched::scheduler::{
    self, Assignment, FailReason, SchedEvent, SchedView, SlotBudget,
};

fn spec(name: &str, user: &str, class: JobClass, maps: usize, reduces: usize) -> JobSpec {
    JobSpec {
        name: name.into(),
        user: user.into(),
        pool: user.into(),
        queue: format!("q_{user}"),
        class,
        priority: Priority::Normal,
        profile: class.base_features(),
        map_works: vec![10.0; maps],
        reduce_works: vec![15.0; reduces],
        submit_time: 0.0,
    }
}

struct Fixture {
    jobs: JobTable,
    hdfs: Namespace,
}

/// Four jobs over two users; job 3's map phase is already complete, so its
/// reduces are the only legally assignable reduces in the fixture.
fn fixture() -> Fixture {
    let mut hdfs = Namespace::new(4, 2, 17);
    let mut jobs = JobTable::new();
    jobs.submit(spec("a", "u0", JobClass::Small, 3, 1), &mut hdfs);
    jobs.submit(spec("b", "u1", JobClass::CpuHeavy, 4, 2), &mut hdfs);
    jobs.submit(spec("c", "u0", JobClass::IoHeavy, 2, 1), &mut hdfs);
    jobs.submit(spec("d", "u1", JobClass::Small, 2, 2), &mut hdfs);
    // drive job 3 (id 3) through its map phase
    for index in 0..2 {
        let t = TaskRef { job: JobId::dense(3), kind: TaskKind::Map, index };
        jobs.start_task(&t, NodeId(0), 1.0);
        jobs.complete_task(&t, 5.0);
    }
    assert!(jobs.get(JobId::dense(3)).maps_complete());
    Fixture { jobs, hdfs }
}

fn big_node() -> Node {
    Node::new(
        NodeId(1),
        NodeSpec { map_slots: 8, reduce_slots: 8, ..Default::default() },
    )
}

fn assign(
    f: &Fixture,
    sched: &mut dyn scheduler::Scheduler,
    node: &Node,
    budget: SlotBudget,
) -> Vec<Assignment> {
    let queue = f.jobs.schedulable();
    let fails = FailureHistory::new();
    let view = SchedView {
        jobs: &f.jobs,
        hdfs: &f.hdfs,
        queue: &queue,
        failures: &fails,
        now: 50.0,
    };
    sched.assign(&view, node, budget)
}

/// The batch contract (see scheduler/api.rs module docs).
fn check_batch(name: &str, f: &Fixture, out: &[Assignment], budget: SlotBudget) {
    let maps = out.iter().filter(|a| a.task.kind == TaskKind::Map).count() as u32;
    let reduces = out.len() as u32 - maps;
    assert!(maps <= budget.maps, "{name}: map budget exceeded ({maps} > {})", budget.maps);
    assert!(
        reduces <= budget.reduces,
        "{name}: reduce budget exceeded ({reduces} > {})",
        budget.reduces
    );
    for (i, a) in out.iter().enumerate() {
        assert!(
            !out[..i].iter().any(|b| b.task == a.task),
            "{name}: task {} assigned twice in one batch",
            a.task
        );
        let job = f.jobs.get(a.task.job);
        if a.decision.speculative {
            // backup copies target RUNNING tasks on a different node
            let task = job.task(&a.task);
            assert!(
                task.is_running(),
                "{name}: speculative copy of non-running {}",
                a.task
            );
            assert!(
                task.speculative.is_none(),
                "{name}: second backup proposed for {}",
                a.task
            );
        } else {
            assert!(
                job.task(&a.task).is_pending(),
                "{name}: assigned non-pending task {}",
                a.task
            );
        }
        if a.task.kind == TaskKind::Reduce && !a.decision.speculative {
            assert!(
                job.maps_complete(),
                "{name}: reduce {} assigned before maps_complete()",
                a.task
            );
        }
        // the decision record must describe the assignment
        assert_eq!(a.decision.job, a.task.job, "{name}: decision/job mismatch");
        assert_eq!(a.decision.kind, a.task.kind, "{name}: decision/kind mismatch");
        assert!(a.decision.candidates > 0, "{name}: zero candidates recorded");
    }
}

#[test]
fn batch_invariants_hold_for_every_scheduler_and_budget() {
    let budgets = [
        SlotBudget { maps: 0, reduces: 0 },
        SlotBudget { maps: 1, reduces: 0 },
        SlotBudget { maps: 0, reduces: 1 },
        SlotBudget { maps: 4, reduces: 2 },
        SlotBudget { maps: 16, reduces: 16 },
    ];
    for name in scheduler::ALL_NAMES {
        for budget in budgets {
            let f = fixture();
            let mut s = scheduler::by_name(name, 7).unwrap();
            s.observe(&SchedEvent::ClusterInfo { total_slots: 32 });
            let out = assign(&f, s.as_mut(), &big_node(), budget);
            check_batch(name, &f, &out, budget);
            if budget.total() == 0 {
                assert!(out.is_empty(), "{name}: assigned with zero budget");
            }
        }
    }
}

#[test]
fn batch_exhausts_work_not_budget() {
    // one small job with 2 maps: a huge budget must yield exactly those 2
    // maps (reduces stay gated), for every scheduler
    for name in scheduler::ALL_NAMES {
        let mut hdfs = Namespace::new(4, 2, 3);
        let mut jobs = JobTable::new();
        jobs.submit(spec("only", "u0", JobClass::Small, 2, 3), &mut hdfs);
        let f = Fixture { jobs, hdfs };
        let mut s = scheduler::by_name(name, 5).unwrap();
        s.observe(&SchedEvent::ClusterInfo { total_slots: 32 });
        let out = assign(&f, s.as_mut(), &big_node(), SlotBudget { maps: 8, reduces: 8 });
        assert_eq!(out.len(), 2, "{name}: expected both maps, got {}", out.len());
        assert!(out.iter().all(|a| a.task.kind == TaskKind::Map), "{name}");
        check_batch(name, &f, &out, SlotBudget { maps: 8, reduces: 8 });
    }
}

#[test]
fn reduces_never_assigned_before_map_phase() {
    // nothing in this fixture has a complete map phase
    for name in scheduler::ALL_NAMES {
        let mut hdfs = Namespace::new(4, 2, 11);
        let mut jobs = JobTable::new();
        jobs.submit(spec("x", "u0", JobClass::Small, 2, 2), &mut hdfs);
        jobs.submit(spec("y", "u1", JobClass::NetHeavy, 3, 4), &mut hdfs);
        let f = Fixture { jobs, hdfs };
        let mut s = scheduler::by_name(name, 2).unwrap();
        let out = assign(&f, s.as_mut(), &big_node(), SlotBudget { maps: 0, reduces: 8 });
        assert!(
            out.is_empty(),
            "{name}: assigned a reduce before any map phase finished"
        );
    }
}

#[test]
fn observe_tolerates_any_event_interleaving() {
    let n0 = NodeId(0);
    let n7 = NodeId(7); // a node id no fixture cluster has
    let m = TaskKind::Map;
    let r = TaskKind::Reduce;
    let events = [
        // never started
        SchedEvent::TaskFinished { job: JobId::dense(9), node: n7, kind: r },
        SchedEvent::Feedback { feats: [9; N_FEATURES], label: Label::Bad },
        SchedEvent::JobCompleted { job: JobId::dense(5) }, // never seen
        SchedEvent::TaskStarted { job: JobId::dense(0), node: n0, kind: m },
        SchedEvent::ClusterInfo { total_slots: 64 },
        SchedEvent::TaskFinished { job: JobId::dense(0), node: n0, kind: m },
        // more finishes than starts
        SchedEvent::TaskFinished { job: JobId::dense(0), node: n0, kind: m },
        // failures for jobs/nodes never seen, in every flavour
        SchedEvent::TaskFailed {
            job: JobId::dense(3),
            node: n7,
            kind: m,
            attempt: 9,
            reason: FailReason::Oom,
        },
        SchedEvent::TaskFailed {
            job: JobId::dense(11),
            node: n0,
            kind: r,
            attempt: 1,
            reason: FailReason::NodeLost,
        },
        SchedEvent::NodeFailed { node: n7 },
        SchedEvent::NodeRecovered { node: n7 },
        SchedEvent::NodeRecovered { node: n0 }, // recover without fail
        SchedEvent::Feedback { feats: [0; N_FEATURES], label: Label::Good },
    ];
    for name in scheduler::ALL_NAMES {
        let mut s = scheduler::by_name(name, 3).unwrap();
        // forward, reversed, and doubled orders must all be absorbed
        for ev in &events {
            s.observe(ev);
        }
        for ev in events.iter().rev() {
            s.observe(ev);
        }
        // assignment still works and still honors the contract afterwards
        let f = fixture();
        let budget = SlotBudget { maps: 4, reduces: 4 };
        let out = assign(&f, s.as_mut(), &big_node(), budget);
        check_batch(name, &f, &out, budget);
    }
}

#[test]
fn observe_between_batches_keeps_batches_valid() {
    // interleave realistic started/finished events with repeated batches;
    // each batch must independently satisfy the contract
    for name in scheduler::ALL_NAMES {
        let f = fixture();
        let mut s = scheduler::by_name(name, 13).unwrap();
        s.observe(&SchedEvent::ClusterInfo { total_slots: 16 });
        let budget = SlotBudget { maps: 2, reduces: 1 };
        for round in 0..4 {
            let out = assign(&f, s.as_mut(), &big_node(), budget);
            check_batch(name, &f, &out, budget);
            for a in &out {
                s.observe(&SchedEvent::TaskStarted {
                    job: a.task.job,
                    node: NodeId(1),
                    kind: a.task.kind,
                });
            }
            if round % 2 == 1 {
                for a in &out {
                    // alternate the two attempt-end flavours: both must
                    // release whatever TaskStarted acquired
                    if a.task.index % 2 == 0 {
                        s.observe(&SchedEvent::TaskFinished {
                            job: a.task.job,
                            node: NodeId(1),
                            kind: a.task.kind,
                        });
                    } else {
                        s.observe(&SchedEvent::TaskFailed {
                            job: a.task.job,
                            node: NodeId(1),
                            kind: a.task.kind,
                            attempt: 1,
                            reason: FailReason::Oom,
                        });
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------- failure churn --

/// Every `by_name` scheduler must survive node fail/recover churn under
/// BOTH drivers: pending feedback cleared on death, no stale generations
/// resurrecting tasks, every job terminating (success or kill), every node
/// draining empty.
#[test]
fn every_scheduler_survives_node_churn_under_both_drivers() {
    use bayes_sched::cluster::Cluster;
    use bayes_sched::coordinator::jobtracker::{
        FailureConfig, JobTracker, TrackerConfig,
    };
    use bayes_sched::workload::generator::{generate, WorkloadConfig};
    use bayes_sched::yarn::{yarn_policy_by_name, ResourceManager, YarnConfig};

    let wl = WorkloadConfig {
        n_jobs: 14,
        arrival_rate: 1.0,
        seed: 77,
        ..Default::default()
    };
    let failures = FailureConfig { mtbf: Some(220.0), mttr: 45.0 };
    for name in scheduler::ALL_NAMES {
        // MRv1 driver
        let mut jt = JobTracker::new(
            Cluster::homogeneous(6, 2),
            scheduler::by_name(name, 77).unwrap(),
            generate(&wl),
            77,
            TrackerConfig { failures, ..Default::default() },
        );
        jt.run();
        assert!(jt.jobs.all_complete(), "{name}: churn stalled the tracker");
        assert_eq!(
            jt.metrics.completed_jobs() + jt.jobs.failed_count(),
            14,
            "{name}: jobs neither completed nor killed"
        );
        for n in &jt.cluster.nodes {
            assert!(
                n.running().is_empty(),
                "{name}: {} still busy after drain",
                n.id
            );
        }
        // the failure history must not leak entries for departed jobs
        assert_eq!(
            jt.failures.tracked_jobs(),
            0,
            "{name}: failure history leaked job entries"
        );

        // YARN driver, same churn
        let mut rm = ResourceManager::new(
            Cluster::homogeneous(6, 2),
            yarn_policy_by_name(name, 1.0).unwrap(),
            generate(&wl),
            77,
            YarnConfig { failures, ..Default::default() },
        );
        rm.run();
        assert!(rm.jobs.all_complete(), "{name}: churn stalled the RM");
        for n in &rm.cluster.nodes {
            assert!(
                n.running().is_empty(),
                "{name}: RM {} still busy after drain",
                n.id
            );
        }
        assert_eq!(
            rm.failures.tracked_jobs(),
            0,
            "{name}: RM failure history leaked job entries"
        );
    }
}

/// Churn runs are deterministic per seed — the per-attempt generation
/// mechanism must not depend on hash iteration or wall time.
#[test]
fn churn_is_deterministic_per_seed() {
    use bayes_sched::cluster::Cluster;
    use bayes_sched::coordinator::jobtracker::{
        FailureConfig, JobTracker, TrackerConfig,
    };
    use bayes_sched::workload::generator::{generate, WorkloadConfig};

    let run = || {
        let wl = WorkloadConfig { n_jobs: 12, seed: 78, ..Default::default() };
        let mut jt = JobTracker::new(
            Cluster::homogeneous(5, 2),
            scheduler::by_name("bayes", 78).unwrap(),
            generate(&wl),
            78,
            TrackerConfig {
                failures: FailureConfig { mtbf: Some(180.0), mttr: 30.0 },
                ..Default::default()
            },
        );
        jt.run();
        (
            jt.metrics.makespan,
            jt.engine.processed(),
            jt.metrics.task_failures,
            jt.metrics.speculative_launches,
            jt.metrics.speculative_wins,
        )
    };
    assert_eq!(run(), run());
}
