//! Memory-regression suite for streaming trace replay: a generated
//! JSONL trace flows through both drivers without ever materializing
//! the spec vector, the parser's resident footprint stays a small
//! constant (chunk + per-record scratch, not O(file)), and the job
//! arena stays O(active) thanks to slot reclamation.

use std::path::PathBuf;

use bayes_sched::cluster::Cluster;
use bayes_sched::coordinator::jobtracker::{JobTracker, TrackerConfig};
use bayes_sched::job::profile::JobClass;
use bayes_sched::scheduler;
use bayes_sched::workload::generator::{stream, Mix, WorkloadConfig};
use bayes_sched::workload::trace::{self, TraceFormat, TraceReader, TraceStats};
use bayes_sched::yarn::{yarn_policy_by_name, ResourceManager, YarnConfig};

const N_JOBS: usize = 1_500;

fn workload() -> WorkloadConfig {
    WorkloadConfig {
        n_jobs: N_JOBS,
        // ~40% of the Small-class service rate on 32 nodes: backlog
        // stays bounded, so peak_active pins reclamation, not overload
        arrival_rate: 1.0,
        mix: Mix::only(JobClass::Small),
        n_users: 8,
        seed: 77,
    }
}

fn write_trace(tag: &str) -> (PathBuf, u64) {
    let path = std::env::temp_dir().join(format!(
        "bayes_sched_stream_test_{tag}_{}.jsonl",
        std::process::id()
    ));
    let n = trace::save_stream(stream(&workload()), &path, TraceFormat::Jsonl)
        .expect("writing trace");
    assert_eq!(n, N_JOBS as u64);
    let bytes = std::fs::metadata(&path).expect("trace metadata").len();
    (path, bytes)
}

#[test]
fn tracker_replay_is_bounded_in_memory() {
    let (path, trace_bytes) = write_trace("mrv1");

    let mut reader = TraceReader::open(&path).expect("opening trace");
    let stats = TraceStats::default();
    reader.install_stats(stats.clone());
    let (specs, errs) = reader.into_stream();

    let cfg = TrackerConfig {
        queue_cap: 64,
        reclaim_jobs: true,
        ..Default::default()
    };
    let mut jt = JobTracker::new_streaming(
        Cluster::homogeneous(32, 4),
        scheduler::by_name("fifo", 77).unwrap(),
        specs,
        77,
        cfg,
    );
    jt.run();
    std::fs::remove_file(&path).ok();

    assert!(errs.take().is_none(), "trace replay hit a decode error");
    assert!(jt.jobs.all_complete());
    assert_eq!(stats.specs_read(), N_JOBS as u64);
    assert_eq!(stats.bytes_read(), trace_bytes);

    // the decode path holds a fixed chunk plus one record of scratch —
    // far below the file, which is the whole point of streaming
    assert!(trace_bytes > 200_000, "trace suspiciously small: {trace_bytes}");
    let peak = stats.resident_peak();
    assert!(peak > 0, "resident gauge never set");
    assert!(
        peak < 64 * 1024 && peak < trace_bytes / 8,
        "parser resident {peak} B is not bounded (trace is {trace_bytes} B)"
    );

    // arena reclamation: slots recycle, so the high-water mark and the
    // end-of-run residency both sit far below the job count
    assert!(
        jt.jobs.peak_active() < N_JOBS / 4,
        "peak_active {} suggests specs were materialized",
        jt.jobs.peak_active()
    );
    assert!(
        jt.jobs.resident() < N_JOBS / 4,
        "resident {} jobs at end of run",
        jt.jobs.resident()
    );
}

#[test]
fn yarn_replay_is_bounded_in_memory() {
    let (path, trace_bytes) = write_trace("yarn");

    let mut reader = TraceReader::open(&path).expect("opening trace");
    let stats = TraceStats::default();
    reader.install_stats(stats.clone());
    let (specs, errs) = reader.into_stream();

    let cfg = YarnConfig {
        queue_cap: 64,
        reclaim_jobs: true,
        ..Default::default()
    };
    let mut rm = ResourceManager::new_streaming(
        Cluster::homogeneous(32, 4),
        yarn_policy_by_name("yarn-fifo", 1.0).unwrap(),
        specs,
        77,
        cfg,
    );
    rm.run();
    std::fs::remove_file(&path).ok();

    assert!(errs.take().is_none(), "trace replay hit a decode error");
    assert!(rm.jobs.all_complete());
    assert_eq!(stats.specs_read(), N_JOBS as u64);

    let peak = stats.resident_peak();
    assert!(
        peak > 0 && peak < 64 * 1024 && peak < trace_bytes / 8,
        "parser resident {peak} B is not bounded (trace is {trace_bytes} B)"
    );
    assert!(rm.jobs.peak_active() < N_JOBS / 4);
    assert!(rm.jobs.resident() < N_JOBS / 4);
}

#[test]
fn streaming_replay_matches_vector_replay() {
    // same trace, streamed vs loaded wholesale: identical completion
    // counts and makespan — streaming changes memory, not behaviour
    let (path, _) = write_trace("equiv");

    let all = trace::load(&path).expect("loading trace");
    let mut a = JobTracker::new(
        Cluster::homogeneous(16, 2),
        scheduler::by_name("fifo", 77).unwrap(),
        all,
        77,
        TrackerConfig::default(),
    );
    a.run();

    let reader = TraceReader::open(&path).expect("opening trace");
    let (specs, errs) = reader.into_stream();
    let mut b = JobTracker::new_streaming(
        Cluster::homogeneous(16, 2),
        scheduler::by_name("fifo", 77).unwrap(),
        specs,
        77,
        TrackerConfig::default(),
    );
    b.run();
    std::fs::remove_file(&path).ok();

    assert!(errs.take().is_none());
    assert_eq!(
        a.metrics.completed_jobs(),
        b.metrics.completed_jobs(),
        "streaming and vector replay diverged"
    );
    // identical event sequence => identical clock -- lint: allow(float-eq)
    assert_eq!(a.metrics.makespan, b.metrics.makespan);
}
