//! Train/serve skew golden tests: the rows the scheduler *learns from*
//! (Feedback) must be bit-identical to the rows it *scored* at decision
//! time (Launched), including the OOM-killed Bad-sample path — the failure
//! mode the ATLAS line of work shows degrades learned schedulers silently.

use std::collections::HashMap;

use bayes_sched::analysis::protocol::{audit_stream, AuditEvent, AuditSink};
use bayes_sched::bayes::classifier::Label;
use bayes_sched::bayes::features::FeatureVec;
use bayes_sched::cluster::node::NodeSpec;
use bayes_sched::cluster::Cluster;
use bayes_sched::coordinator::jobtracker::{JobTracker, TrackerConfig};
use bayes_sched::job::profile::JobClass;
use bayes_sched::scheduler::api::{FailReason, SchedEvent};
use bayes_sched::scheduler::by_name;
use bayes_sched::workload::generator::{generate, Mix, WorkloadConfig};
use bayes_sched::yarn::{yarn_policy_by_name, ResourceManager, YarnConfig};

/// Small cluster with generous slots + mem-heavy-only jobs: guaranteed
/// OOM kills, so the Bad-sample feedback path is exercised.
fn oomy_workload(seed: u64) -> (Cluster, Vec<bayes_sched::job::job::JobSpec>) {
    let cluster = Cluster::with_specs(
        (0..3)
            .map(|_| NodeSpec { map_slots: 4, reduce_slots: 2, ..Default::default() })
            .collect(),
        1,
    );
    let wl = WorkloadConfig {
        n_jobs: 20,
        arrival_rate: 2.0,
        mix: Mix::only(JobClass::MemHeavy),
        seed,
        ..Default::default()
    };
    (cluster, generate(&wl))
}

fn recorded_mrv1(sched: &str, seed: u64) -> Vec<AuditEvent> {
    let (cluster, specs) = oomy_workload(seed);
    let mut jt = JobTracker::new(
        cluster,
        by_name(sched, seed).unwrap(),
        specs,
        seed,
        TrackerConfig::default(),
    );
    jt.set_audit(AuditSink::recording());
    jt.run();
    assert!(jt.metrics.oom_kills > 0, "workload produced no OOM kills");
    jt.audit.take_recording()
}

fn recorded_yarn(sched: &str, seed: u64) -> Vec<AuditEvent> {
    let (cluster, specs) = oomy_workload(seed);
    let mut rm = ResourceManager::new(
        cluster,
        yarn_policy_by_name(sched, 1.0).unwrap(),
        specs,
        seed,
        YarnConfig::default(),
    );
    rm.set_audit(AuditSink::recording());
    rm.run();
    assert!(rm.metrics.oom_kills > 0, "workload produced no OOM kills");
    rm.audit.take_recording()
}

/// Every Feedback row must appear among the Launched decision rows —
/// checked directly against the stream, independent of the auditor.
fn assert_no_skew(events: &[AuditEvent]) {
    let mut scored: HashMap<FeatureVec, u64> = HashMap::new();
    let mut feedback_rows = 0u64;
    let mut bad_rows = 0u64;
    let mut oom_fails = 0u64;
    for ev in events {
        match ev {
            AuditEvent::Launched { feats, .. } => {
                *scored.entry(*feats).or_insert(0) += 1;
            }
            AuditEvent::Sched(SchedEvent::Feedback { feats, label }) => {
                feedback_rows += 1;
                if *label == Label::Bad {
                    bad_rows += 1;
                }
                assert!(
                    scored.contains_key(feats),
                    "feedback row {feats:?} was never scored at decision time"
                );
            }
            AuditEvent::Sched(SchedEvent::TaskFailed {
                reason: FailReason::Oom,
                ..
            }) => oom_fails += 1,
            _ => {}
        }
    }
    assert!(feedback_rows > 0, "no feedback at all");
    assert!(oom_fails > 0, "no OOM failures recorded");
    assert!(
        bad_rows > 0,
        "OOM kills happened but no Bad feedback row was emitted"
    );
}

#[test]
fn mrv1_feedback_rows_match_decision_rows_including_oom_path() {
    let events = recorded_mrv1("bayes", 14);
    assert_no_skew(&events);
    // and the protocol auditor agrees (train-serve-skew is rule R8)
    let violations = audit_stream(&events);
    assert!(violations.is_empty(), "{violations:?}");
}

#[test]
fn yarn_feedback_rows_match_decision_rows_including_oom_path() {
    let events = recorded_yarn("bayes", 14);
    assert_no_skew(&events);
    let violations = audit_stream(&events);
    assert!(violations.is_empty(), "{violations:?}");
}

#[test]
fn feedback_stream_is_deterministic_golden() {
    // same seed, same config -> bit-identical feedback row sequence; any
    // drift here means decision rows and training rows can drift apart too
    let rows = |events: &[AuditEvent]| -> Vec<(FeatureVec, Label)> {
        events
            .iter()
            .filter_map(|ev| match ev {
                AuditEvent::Sched(SchedEvent::Feedback { feats, label }) => {
                    Some((*feats, *label))
                }
                _ => None,
            })
            .collect()
    };
    let a = rows(&recorded_mrv1("bayes", 31));
    let b = rows(&recorded_mrv1("bayes", 31));
    assert!(!a.is_empty());
    assert_eq!(a, b, "feedback stream not reproducible for identical runs");
}
