//! Differential fuzz suite: the streaming pull tokenizer (which now
//! backs `Json::parse`) against the original recursive parser kept as
//! an oracle in `config::json::reference`. Both must agree on
//! accept/reject AND on the parsed value for every document here —
//! including hostile ones: deep nesting at the cap, huge numbers,
//! truncated prefixes, invalid `\u` escapes, raw control bytes, and a
//! reader that delivers the document one byte per `read()` call.

use std::io::Read;

use bayes_sched::config::json::pull::{PullParser, MAX_DEPTH};
use bayes_sched::config::json::{reference, Json};

/// Assert the oracle and the pull-backed parser agree on `text`.
fn agree(text: &str) {
    let tree = reference::parse(text);
    let pull = Json::parse(text);
    match (&tree, &pull) {
        (Ok(a), Ok(b)) => assert_eq!(a, b, "values differ for {text:?}"),
        (Err(_), Err(_)) => {}
        _ => panic!("disagreement on {text:?}: tree={tree:?} pull={pull:?}"),
    }
}

/// Documents both parsers accept (also fed to the truncation sweep).
const VALID: &[&str] = &[
    "null",
    "true",
    "false",
    "0",
    "-0",
    "3.5",
    "1e3",
    "1E3",
    "2.5e-2",
    "-12.75e+1",
    "[]",
    "{}",
    r#""""#,
    r#""a""#,
    r#""\n\t\\\/\"\b\f\r""#,
    r#""Aé""#,
    "\"\u{3c0} and text\"",
    r#"{"a":[1,{"b":null},"x"],"c":true,"d":[[],{}]}"#,
    "  [ 1 ,\t2 , \n3 ]  ",
    r#"[[],[[]],{"":{}}]"#,
    r#"{"a":1,"a":2}"#,
    "[0.5,-2e10,1e999]",
];

/// Documents both parsers reject.
const INVALID: &[&str] = &[
    "",
    "   ",
    "nul",
    "tru",
    "truex",
    "[1,]",
    "[,1]",
    "[1 2]",
    "[1,2",
    r#"{"a":}"#,
    r#"{"a"1}"#,
    r#"{"a":1,}"#,
    "{a:1}",
    r#"{"a":1"#,
    "\"abc",
    r#""\q""#,
    "-",
    "+1",
    ".5",
    "1e",
    "1e+",
    "1 2",
    "[] []",
    "{}x",
    "]",
    "}",
    ",",
    ":",
];

#[test]
fn corpus_agrees() {
    for doc in VALID {
        agree(doc);
        assert!(Json::parse(doc).is_ok(), "expected accept: {doc:?}");
    }
    for doc in INVALID {
        agree(doc);
        assert!(Json::parse(doc).is_err(), "expected reject: {doc:?}");
    }
}

#[test]
fn every_truncated_prefix_agrees() {
    for doc in VALID {
        for i in 0..doc.len() {
            if doc.is_char_boundary(i) {
                agree(&doc[..i]);
            }
        }
    }
}

#[test]
fn deep_nesting_errors_at_the_shared_cap() {
    let ok = "[".repeat(MAX_DEPTH) + &"]".repeat(MAX_DEPTH);
    let a = reference::parse(&ok).expect("oracle accepts depth == MAX_DEPTH");
    let b = Json::parse(&ok).expect("pull accepts depth == MAX_DEPTH");
    assert_eq!(a, b);

    let deep = "[".repeat(MAX_DEPTH + 1) + &"]".repeat(MAX_DEPTH + 1);
    assert!(reference::parse(&deep).is_err());
    assert!(Json::parse(&deep).is_err());

    // mixed object nesting hits the same cap
    let n = MAX_DEPTH + 1;
    let mixed = r#"{"k":"#.repeat(n) + "1" + &"}".repeat(n);
    assert!(reference::parse(&mixed).is_err());
    assert!(Json::parse(&mixed).is_err());
}

#[test]
fn huge_numbers_agree_and_as_u64_respects_the_boundary() {
    for doc in [
        "18446744073709551616",  // 2^64
        "18446744073709551615",  // u64::MAX (rounds up to 2^64 in f64)
        "9007199254740992",      // 2^53: exactly representable
        "1e999",                 // overflows to +inf in both
        "-1e999",
        "1e-999",
        "123456789012345678901234567890",
    ] {
        agree(doc);
    }
    // 2^53 round-trips exactly
    assert_eq!(
        Json::parse("9007199254740992").unwrap().as_u64(),
        Some(9_007_199_254_740_992)
    );
    // at and past 2^64 the f64 saturates — as_u64 must refuse, not clamp
    assert_eq!(Json::parse("18446744073709551616").unwrap().as_u64(), None);
    assert_eq!(Json::parse("18446744073709551615").unwrap().as_u64(), None);
    assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
    assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
    assert_eq!(Json::parse("1e999").unwrap().as_u64(), None);
    assert_eq!(Json::parse("0").unwrap().as_u64(), Some(0));
}

#[test]
fn surrogate_escapes_agree() {
    // a valid pair decodes to the astral scalar in both parsers
    let pair = concat!(r#""\ud83d"#, r#"\ude00""#);
    agree(pair);
    assert_eq!(
        Json::parse(pair).unwrap(),
        Json::Str("\u{1F600}".to_string())
    );

    // lone and mismatched surrogates, bad hex, truncated escapes
    let high_then_scalar = concat!(r#""\ud83d"#, r#"A""#);
    let high_then_escape = concat!(r#""\ud83d"#, r#"\n""#);
    for doc in [
        r#""\ud83d""#,        // lone high
        r#""\ude00""#,        // lone low
        r#""\ud83dAB""#,      // high then raw chars
        high_then_scalar,     // high then non-low unit
        high_then_escape,     // high then a non-\u escape
        r#""\u12G4""#,        // bad hex digit
        r#""\u12"#,           // truncated escape + unterminated string
        r#""\u""#,
    ] {
        agree(doc);
        assert!(Json::parse(doc).is_err(), "expected reject: {doc:?}");
    }
}

#[test]
fn raw_control_characters_pass_through_identically() {
    // both parsers deliberately let raw control bytes through inside
    // strings (documented in pull.rs) — what matters is they agree
    for (doc, want) in [
        ("\"a\u{0001}b\"", "a\u{0001}b"),
        ("\"a\u{0000}\"", "a\u{0000}"),
        ("\"line\nbreak\"", "line\nbreak"),
    ] {
        agree(doc);
        assert_eq!(
            Json::parse(doc).unwrap(),
            Json::Str(want.to_string()),
            "{doc:?}"
        );
    }
}

#[test]
fn invalid_utf8_bytes_error_in_the_pull_parser() {
    // the oracle takes &str so raw invalid UTF-8 can only reach the
    // byte-oriented pull parser — it must error, not panic or mangle
    for doc in [&b"\"\xff\""[..], &b"[\"\xc3\x28\"]"[..], &b"{\xff}"[..]] {
        let mut p = PullParser::from_slice(doc);
        let r = (|| -> Result<(), bayes_sched::config::json::JsonError> {
            while p.next()?.is_some() {}
            Ok(())
        })();
        assert!(r.is_err(), "expected reject: {doc:?}");
    }
}

/// A reader that returns one byte per `read()` call — worst-case
/// chunking for the pull parser's buffered refill path.
struct OneByte<'a> {
    data: &'a [u8],
    pos: usize,
}

impl Read for OneByte<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.data.len() || buf.is_empty() {
            return Ok(0);
        }
        buf[0] = self.data[self.pos];
        self.pos += 1;
        Ok(1)
    }
}

fn tokens<R: Read>(mut p: PullParser<R>) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    loop {
        match p.next() {
            Ok(Some(t)) => out.push(format!("{t:?}")),
            Ok(None) => return Ok(out),
            Err(e) => return Err(e.to_string()),
        }
    }
}

#[test]
fn one_byte_reads_token_identically_to_the_slice_path() {
    for doc in VALID.iter().chain(INVALID.iter()) {
        let whole = tokens(PullParser::from_slice(doc.as_bytes()));
        let chunked = tokens(PullParser::new(OneByte {
            data: doc.as_bytes(),
            pos: 0,
        }));
        assert_eq!(whole, chunked, "chunking changed the outcome for {doc:?}");
    }
}
